"""The unified session API: QuerySpec validation, the deprecated
QueryEngine shim's submit/submit_many delegation, union predicates,
materialization policy, trainer registry, batch cost attribution."""
import numpy as np
import pytest

from repro.api import (
    Interval,
    MLegoSession,
    QuerySpec,
    available_trainers,
    get_trainer,
    normalize_sigma,
    register_trainer,
    resolve_kind,
)
from repro.configs.lda_default import LDAConfig
from repro.core.query import QueryEngine
from repro.core.store import ModelStore
from repro.data.corpus import make_corpus, train_test_split

CFG = LDAConfig(n_topics=6, vocab_size=150, alpha=0.5, eta=0.05,
                max_iters=12, e_step_iters=8, gibbs_sweeps=8)


@pytest.fixture(scope="module")
def train():
    corpus, _ = make_corpus(350, CFG.vocab_size, CFG.n_topics,
                            mean_doc_len=40, seed=3)
    train, _ = train_test_split(corpus, test_frac=0.15, seed=1)
    return train


def _session(train, kind="vb"):
    return MLegoSession(train, CFG, kind=kind, seed=0)


# ---------------------------------------------------------------------------
# QuerySpec validation / normalization
# ---------------------------------------------------------------------------

def test_spec_normalizes_union():
    spec = QuerySpec(sigma=[Interval(200.0, 300.0), Interval(0.0, 100.0),
                            Interval(90.0, 150.0)])
    assert spec.sigma == (Interval(0.0, 150.0), Interval(200.0, 300.0))
    assert spec.is_union
    assert spec.span == Interval(0.0, 300.0)


def test_spec_coalesces_touching_intervals():
    spec = QuerySpec(sigma=[Interval(0.0, 100.0), Interval(100.0, 200.0)])
    assert spec.sigma == (Interval(0.0, 200.0),)
    assert not spec.is_union


@pytest.mark.parametrize("bad", [
    dict(sigma=[]),
    dict(sigma=Interval(0.0, 100.0), alpha=1.5),
    dict(sigma=Interval(0.0, 100.0), alpha=-0.1),
    dict(sigma=Interval(0.0, 100.0), method="magic"),
    dict(sigma=Interval(0.0, 100.0), materialize="maybe"),
    dict(sigma=Interval(50.0, 50.0)),
])
def test_spec_rejects_invalid(bad):
    with pytest.raises((ValueError, TypeError)):
        QuerySpec(**bad)


def test_spec_canonicalizes_gibbs_alias():
    spec = QuerySpec(sigma=Interval(0.0, 10.0), kind="gibbs")
    assert spec.kind == "gs"


def test_alias_tagged_legacy_store_is_reused(train):
    """Stores persisted by the old engine may tag models with an alias
    ("gibbs") — the session must still find and merge that capital."""
    sess = _session(train, kind="gs")
    m = sess.train_range(0.0, 350.0)
    # simulate a legacy store entry: same Θ, alias kind tag
    sess.store.remove(m.model_id)
    legacy = sess.store.add(m.o, m.n_docs, m.n_tokens, "gibbs", m.theta)
    rep = sess.submit(QuerySpec(sigma=Interval(0.0, 350.0), alpha=1.0))
    assert rep.n_trained_tokens == 0, "alias-tagged capital was orphaned"
    assert rep.model_ids == (legacy.model_id,)
    assert np.isfinite(rep.beta).all()


def test_submit_defaults_to_session_kind(train):
    """A spec with no explicit kind must use the session's backend —
    including consulting that backend's reuse capital."""
    sess = _session(train, kind="gs")
    m = sess.train_range(0.0, 350.0)
    assert m.kind == "gs"
    rep = sess.submit(QuerySpec(sigma=Interval(0.0, 350.0), alpha=1.0))
    assert rep.n_trained_tokens == 0, "session-kind capital must be reused"
    assert rep.model_ids == (m.model_id,)
    assert all(mm.kind == "gs" for mm in sess.store.models())
    # batch path too
    br = sess.submit_many([QuerySpec(sigma=Interval(0.0, 200.0))])
    assert all(mm.kind == "gs" for mm in br.materialized)


# ---------------------------------------------------------------------------
# trainer registry
# ---------------------------------------------------------------------------

def test_registry_rejects_unknown_kind():
    with pytest.raises(ValueError, match="unknown model kind"):
        resolve_kind("not-a-trainer")
    with pytest.raises(ValueError, match="unknown model kind"):
        get_trainer("not-a-trainer")
    with pytest.raises(ValueError, match="unknown model kind"):
        QuerySpec(sigma=Interval(0.0, 10.0), kind="not-a-trainer")


def test_registry_builtin_kinds():
    assert {"vb", "gs"} <= set(available_trainers())
    assert resolve_kind("gibbs") == "gs"


def test_registered_trainer_plugs_into_submit(train):
    calls = []

    def fake_vb(corpus, cfg, key):
        calls.append(corpus.n_docs)
        return get_trainer("vb")(corpus, cfg, key)

    register_trainer("fake_vb", fake_vb)
    try:
        sess = _session(train, kind="fake_vb")
        rep = sess.submit(QuerySpec(sigma=Interval(0.0, 120.0),
                                    kind="fake_vb"))
        assert calls, "custom trainer was never invoked"
        assert np.isfinite(rep.beta).all()
        assert all(m.kind == "fake_vb" for m in sess.store.models())
    finally:
        from repro.api import trainers as tr
        tr._TRAINERS.pop("fake_vb", None)
        tr._MERGES.pop("fake_vb", None)


# ---------------------------------------------------------------------------
# submit vs deprecated QueryEngine shim equivalence
# ---------------------------------------------------------------------------

def _legacy_engine(train, kind="vb"):
    with pytest.warns(DeprecationWarning, match="QueryEngine is deprecated"):
        return QueryEngine(train, ModelStore(), CFG, kind=kind, seed=0)


@pytest.mark.parametrize("kind", ["vb", "gs"])
def test_submit_matches_legacy_execute(train, kind):
    sess = _session(train, kind=kind)
    sess.train_range(0.0, 170.0)
    rep = sess.submit(QuerySpec(sigma=Interval(0.0, 350.0), alpha=0.5,
                                kind=kind))

    engine = _legacy_engine(train, kind)
    engine.train_range(0.0, 170.0)
    res = engine.execute(Interval(0.0, 350.0), alpha=0.5)

    np.testing.assert_array_equal(rep.beta, res.beta)
    assert rep.n_trained_tokens == res.n_trained_tokens
    assert rep.n_merged == res.n_merged
    assert rep.plan.model_ids == res.plan.model_ids


def test_submit_many_matches_legacy_execute_batch(train):
    queries = [Interval(0.0, 200.0), Interval(100.0, 300.0)]

    sess = _session(train)
    sess.train_range(0.0, 120.0)
    br = sess.submit_many([QuerySpec(sigma=q) for q in queries])

    engine = _legacy_engine(train)
    engine.train_range(0.0, 120.0)
    results, opt = engine.execute_batch(queries)

    assert len(br) == len(results) == 2
    for rep, res in zip(br, results):
        np.testing.assert_array_equal(rep.beta, res.beta)
        assert rep.n_merged == res.n_merged
    assert br.opt.benefit == pytest.approx(opt.benefit)


# ---------------------------------------------------------------------------
# union-of-intervals predicates
# ---------------------------------------------------------------------------

def test_union_predicate_merges_the_right_parts(train):
    sess = _session(train)
    m_left = sess.train_range(0.0, 100.0)
    m_mid = sess.train_range(150.0, 250.0)    # inside the union's hole
    m_right = sess.train_range(260.0, 350.0)

    rep = sess.submit(QuerySpec(
        sigma=[Interval(0.0, 100.0), Interval(260.0, 350.0)], alpha=1.0))

    assert rep.n_trained_tokens == 0, "both components fully covered"
    assert rep.model_ids == tuple(sorted(
        (m_left.model_id, m_right.model_id)))
    assert m_mid.model_id not in rep.model_ids, \
        "model inside the predicate hole must not be merged"
    assert len(rep.plans) == 2
    np.testing.assert_allclose(rep.beta.sum(1), 1.0, rtol=1e-4)


def test_union_predicate_trains_only_inside_components(train):
    sess = _session(train)
    rep = sess.submit(QuerySpec(
        sigma=[Interval(0.0, 80.0), Interval(200.0, 280.0)]))
    for m in rep.materialized:
        assert (Interval(0.0, 80.0).contains(m.o)
                or Interval(200.0, 280.0).contains(m.o)), m.o
    # the hole stays untrained
    assert all(not m.o.overlaps(Interval(80.0, 200.0))
               for m in sess.store.models())


def test_union_predicate_in_batch(train):
    sess = _session(train)
    specs = [
        QuerySpec(sigma=[Interval(0.0, 80.0), Interval(200.0, 280.0)]),
        QuerySpec(sigma=Interval(50.0, 250.0)),
    ]
    br = sess.submit_many(specs)
    assert len(br) == 2
    assert len(br.reports[0].plans) == 2      # one plan per component
    assert len(br.reports[1].plans) == 1
    for rep in br:
        assert np.isfinite(rep.beta).all()


# ---------------------------------------------------------------------------
# materialization policy
# ---------------------------------------------------------------------------

def test_volatile_policy_leaves_store_unchanged(train):
    sess = _session(train)
    sess.train_range(0.0, 100.0)
    n0 = len(sess.store)
    rep = sess.submit(QuerySpec(sigma=Interval(0.0, 200.0),
                                materialize="volatile"))
    assert len(sess.store) == n0, "volatile query must not grow the store"
    assert rep.n_trained_tokens > 0, "the gap was still trained"
    assert all(m.model_id == -1 for m in rep.materialized)


def test_persist_policy_grows_store(train):
    sess = _session(train)
    n0 = len(sess.store)
    rep = sess.submit(QuerySpec(sigma=Interval(0.0, 200.0)))
    assert len(sess.store) > n0
    assert all(m.model_id >= 0 for m in rep.materialized)


def test_mixed_kind_batch_rejected(train):
    sess = _session(train)
    with pytest.raises(ValueError, match="one backend kind"):
        sess.submit_many([QuerySpec(sigma=Interval(0.0, 100.0), kind="vb"),
                          QuerySpec(sigma=Interval(0.0, 100.0), kind="gs")])


def test_batch_splits_mixed_alpha_specs(train):
    """A mixed-alpha batch is auto-split into per-alpha sub-batches —
    every weight honored, reports back in submission order."""
    def covered_session():
        s = _session(train)
        s.train_range(0.0, 100.0)
        s.train_range(100.0, 120.0)
        return s

    sess = covered_session()
    specs = [QuerySpec(sigma=Interval(0.0, 100.0), alpha=0.5),
             QuerySpec(sigma=Interval(0.0, 120.0), alpha=0.0),
             QuerySpec(sigma=Interval(0.0, 100.0), alpha=0.5)]
    br = sess.submit_many(specs)
    assert len(br) == 3
    for rep, spec in zip(br.reports, specs):
        assert rep.spec is spec, "reports must stay in submission order"
        assert np.isfinite(rep.beta).all()
    assert br.opt.method == "ALG4/alpha-split"
    # parity with the single-alpha paths, query by query (the store
    # covers every query, so answers are key-stream independent)
    for i, spec in enumerate(specs):
        solo = covered_session()
        np.testing.assert_allclose(
            solo.submit_many([spec]).reports[0].beta, br.reports[i].beta,
            rtol=1e-5, atol=1e-5)


def test_batch_split_rejects_mixed_kinds_and_backends(train):
    """Auto-split covers alpha only — kind/backend stay batch-wide
    contracts even when the alphas differ."""
    sess = _session(train)
    with pytest.raises(ValueError, match="one backend kind"):
        sess.submit_many([
            QuerySpec(sigma=Interval(0.0, 100.0), alpha=0.5, kind="vb"),
            QuerySpec(sigma=Interval(0.0, 100.0), alpha=0.0, kind="gs")])
    with pytest.raises(ValueError, match="one execution backend"):
        sess.submit_many([
            QuerySpec(sigma=Interval(0.0, 100.0), alpha=0.5, backend="host"),
            QuerySpec(sigma=Interval(0.0, 100.0), alpha=0.0,
                      backend="device")])


def test_batch_threads_uniform_alpha(train):
    """A uniform alpha > 0 batch is accepted and the weight reaches the
    initial per-query plans (BatchResult.alpha records it)."""
    sess = _session(train)
    sess.train_range(0.0, 120.0)
    br = sess.submit_many([QuerySpec(sigma=Interval(0.0, 200.0), alpha=0.5),
                           QuerySpec(sigma=Interval(50.0, 250.0), alpha=0.5)])
    assert br.opt.alpha == 0.5
    assert all(np.isfinite(r.beta).all() for r in br)


def test_alias_cannot_shadow_registered_kind():
    with pytest.raises(ValueError, match="shadow"):
        register_trainer("other", get_trainer("vb"), aliases=("vb",))
    assert resolve_kind("vb") == "vb"
    from repro.api import trainers as tr
    tr._TRAINERS.pop("other", None)
    tr._MERGES.pop("other", None)


# ---------------------------------------------------------------------------
# DSGS global prior threading (store's merged counts -> gap training)
# ---------------------------------------------------------------------------

def test_gs_gap_trains_against_store_merged_counts(train, monkeypatch):
    """A gs gap must sample against the store's merged N_kv (Eq. 8),
    not the seed's zero prior."""
    import repro.api.trainers as tr

    seen = {}
    real = tr.cgs_fit

    def spy(tokens, doc_ids, cfg, key, global_nkv=None, sweeps=None):
        seen["global_nkv"] = global_nkv
        return real(tokens, doc_ids, cfg, key, global_nkv=global_nkv,
                    sweeps=sweeps)

    monkeypatch.setattr(tr, "cgs_fit", spy)
    sess = _session(train, kind="gs")
    m = sess.train_range(0.0, 150.0)          # cold store: zero prior
    assert seen["global_nkv"] is None
    sess.submit(QuerySpec(sigma=Interval(0.0, 300.0)))  # gap 150..300
    assert seen["global_nkv"] is not None, \
        "warm store must thread its merged counts as the DSGS prior"
    np.testing.assert_array_equal(seen["global_nkv"],
                                  m.theta["delta_nkv"])


def test_gs_prior_sums_all_store_counts(train, monkeypatch):
    import repro.api.trainers as tr

    seen = {}
    real = tr.cgs_fit

    def spy(tokens, doc_ids, cfg, key, global_nkv=None, sweeps=None):
        seen["global_nkv"] = global_nkv
        return real(tokens, doc_ids, cfg, key, global_nkv=global_nkv,
                    sweeps=sweeps)

    monkeypatch.setattr(tr, "cgs_fit", spy)
    sess = _session(train, kind="gs")
    m1 = sess.train_range(0.0, 100.0)
    m2 = sess.train_range(100.0, 200.0)       # trained under m1's prior
    np.testing.assert_array_equal(seen["global_nkv"],
                                  m1.theta["delta_nkv"])
    sess.submit(QuerySpec(sigma=Interval(0.0, 300.0)))  # gap 200..300
    np.testing.assert_allclose(
        seen["global_nkv"],
        m1.theta["delta_nkv"] + m2.theta["delta_nkv"], rtol=1e-6)


def test_custom_trainer_without_prior_kwarg_still_works(train):
    """The registry contract stays (corpus, cfg, key) — trainers that
    don't declare global_nkv never receive it."""
    def plain_gs(corpus, cfg, key):
        return get_trainer("gs")(corpus, cfg, key)

    register_trainer("plain_gs", plain_gs, merge="gs")
    try:
        sess = _session(train, kind="plain_gs")
        sess.train_range(0.0, 100.0)
        rep = sess.submit(QuerySpec(sigma=Interval(0.0, 200.0)))
        assert np.isfinite(rep.beta).all()
    finally:
        from repro.api import trainers as tr
        tr._TRAINERS.pop("plain_gs", None)
        tr._MERGES.pop("plain_gs", None)


# ---------------------------------------------------------------------------
# batch cost attribution (regression for the results[0] smearing bug)
# ---------------------------------------------------------------------------

def test_batch_costs_live_on_the_batch_report(train):
    sess = _session(train)
    sess.train_range(0.0, 120.0)
    br = sess.submit_many([QuerySpec(sigma=Interval(0.0, 200.0)),
                           QuerySpec(sigma=Interval(100.0, 300.0))])
    # per-query reports carry only their own merge time
    for rep in br:
        assert rep.train_s == 0.0
        assert rep.search_s == 0.0
        assert rep.merge_s > 0.0
    assert br.shared_train_s > 0.0
    assert br.total_s == pytest.approx(
        br.shared_search_s + br.shared_train_s
        + sum(r.merge_s for r in br))


def test_legacy_batch_totals_preserved(train):
    """The shim no longer smears shared costs onto results[0]: inside a
    batch every per-query report carries only its own merge time, and
    the shared search/train terms live on ``last_batch_report`` — the
    aggregate total is unchanged."""
    engine = _legacy_engine(train)
    engine.train_range(0.0, 120.0)
    results, _ = engine.execute_batch([Interval(0.0, 200.0),
                                       Interval(100.0, 300.0)])
    br = engine.last_batch_report
    assert all(r.train_s == 0.0 and r.search_s == 0.0 for r in results)
    assert br.shared_train_s > 0.0
    assert br.total_s == pytest.approx(
        br.shared_train_s + br.shared_search_s
        + sum(r.merge_s for r in results))


# ---------------------------------------------------------------------------
# misc session behavior
# ---------------------------------------------------------------------------

def test_session_store_stays_assignable(train):
    """Legacy code swaps in a loaded store by assignment; the session's
    ``store`` setter rewires the planner/executor/cache plumbing so the
    assigned store is the one training materializes into."""
    sess = _session(train)
    other = ModelStore()
    sess.store = other
    assert sess.store is other
    m = sess.train_range(0.0, 100.0)
    assert m.model_id in {mm.model_id for mm in other.models()}, \
        "assigned store must be the one training materializes into"
    # the shim inherits the same surface
    engine = _legacy_engine(train)
    engine.store = other
    assert engine.store is other


def test_empty_query_raises(train):
    sess = _session(train)
    hi = float(train.attr[-1])
    with pytest.raises(ValueError, match="selects no data"):
        sess.submit(QuerySpec(sigma=Interval(hi + 10.0, hi + 20.0)))


def test_normalize_sigma_rejects_non_interval():
    with pytest.raises(TypeError):
        normalize_sigma([(0.0, 1.0)])
