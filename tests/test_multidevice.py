"""Multi-device parity tests.

These need >1 XLA device, so each runs in a subprocess with
``--xla_force_host_platform_device_count=8`` (the main pytest process
must keep the single real CPU device for the smoke tests).
"""
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(body: str, devices: int = 8, timeout: int = 420):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    proc = subprocess.run([sys.executable, "-c", textwrap.dedent(body)],
                          env=env, capture_output=True, text=True,
                          timeout=timeout)
    assert proc.returncode == 0, proc.stdout + "\n" + proc.stderr
    return proc.stdout


COMMON = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.distributed.sharding import MeshEnv
mesh = jax.make_mesh((2, 4), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
env = MeshEnv(mesh=mesh)
"""


def test_ring_attention_matches_local():
    run_sub(COMMON + """
from repro.models.attention import ring_attention, flash_attention_local
rng = np.random.default_rng(0)
B, S, H, KVH, hd = 4, 64, 4, 2, 16
q = jnp.asarray(rng.normal(size=(B,S,H,hd)), jnp.float32)
k = jnp.asarray(rng.normal(size=(B,S,KVH,hd)), jnp.float32)
v = jnp.asarray(rng.normal(size=(B,S,KVH,hd)), jnp.float32)
with mesh:
    out = ring_attention(q, k, v, env=env, causal=True)
ref = flash_attention_local(q, k, v, jnp.arange(S), jnp.arange(S), causal=True)
np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)
# windowed
with mesh:
    out = ring_attention(q, k, v, env=env, causal=True, window=24)
ref = flash_attention_local(q, k, v, jnp.arange(S), jnp.arange(S), causal=True, window=24)
np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)
print("ring OK")
""")


def test_decode_attention_matches_local():
    run_sub(COMMON + """
from repro.models.attention import decode_attention
from repro.kernels.flash_attention.ref import decode_attention_ref
rng = np.random.default_rng(1)
B, S, H, KVH, hd = 4, 64, 4, 2, 16
q = jnp.asarray(rng.normal(size=(B,1,H,hd)), jnp.float32)
kc = jnp.asarray(rng.normal(size=(B,S,KVH,hd)), jnp.float32)
vc = jnp.asarray(rng.normal(size=(B,S,KVH,hd)), jnp.float32)
kn = jnp.asarray(rng.normal(size=(B,1,KVH,hd)), jnp.float32)
vn = jnp.asarray(rng.normal(size=(B,1,KVH,hd)), jnp.float32)
pos = jnp.asarray(40, jnp.int32)
with mesh:
    out, kc2, vc2 = decode_attention(q, kc, vc, kn, vn, pos, env=env)
kc_ref = kc.at[:, 40].set(kn[:, 0]); vc_ref = vc.at[:, 40].set(vn[:, 0])
ref = decode_attention_ref(q, kc_ref, vc_ref, 40)
np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)
np.testing.assert_allclose(np.asarray(kc2), np.asarray(kc_ref))
print("decode OK")
""")


def test_vb_fit_sharded_matches_single():
    run_sub(COMMON + """
from repro.configs.lda_default import LDAConfig
from repro.core.vb import vb_fit, vb_fit_sharded
cfg = LDAConfig(n_topics=4, vocab_size=64, max_iters=5, e_step_iters=4)
rng = np.random.default_rng(2)
x = jnp.asarray(rng.poisson(0.4, (16, 64)), jnp.float32)
key = jax.random.PRNGKey(0)
with mesh:
    lam_sh = vb_fit_sharded(x, key, cfg, env)
lam_sh = np.asarray(lam_sh)
# sharded init differs (per-shard RNG); compare the *topics* they imply
# on a run from identical init: rerun single with the merged-lam init is
# not equivalent, so instead check fixed-point property: one more
# sharded outer iteration barely moves lam (converged) and shapes/mass
# are sane.
assert lam_sh.shape == (4, 64)
assert np.isfinite(lam_sh).all()
assert (lam_sh > 0).all()
# and: DP psum of sufficient stats == Alg.1 merge — verify by comparing
# against a manual two-partition merge with the same global beta.
from repro.core.vb import vb_estep, _exp_dirichlet_expectation
lam0 = jnp.asarray(rng.gamma(100.0, 0.01, (4, 64)), jnp.float32)
eeb = _exp_dirichlet_expectation(lam0)
g0 = jnp.ones((8, 4), jnp.float32)
_, s1 = vb_estep(x[:8], eeb, g0, cfg.alpha, 4)
_, s2 = vb_estep(x[8:], eeb, g0, cfg.alpha, 4)
_, s_all = vb_estep(x, eeb, jnp.ones((16, 4), jnp.float32), cfg.alpha, 4)
np.testing.assert_allclose(np.asarray(s1 + s2), np.asarray(s_all), rtol=1e-4, atol=1e-4)
print("vb OK")
""")


def test_merge_collective_matches_host():
    run_sub(COMMON + """
from repro.distributed.merge_collective import merge_stats
rng = np.random.default_rng(3)
eta = 0.05
stats = jnp.asarray(rng.gamma(1.0, 1.0, (8, 4, 64)), jnp.float32)
with mesh:
    merged = merge_stats(stats, env, kind="vb", eta=eta)
ref = eta + (np.asarray(stats) - eta).sum(0)
np.testing.assert_allclose(np.asarray(merged), ref, rtol=1e-5, atol=1e-5)
print("merge collective OK")
""")


def test_pipeline_matches_sequential():
    run_sub("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.distributed.sharding import MeshEnv
from repro.distributed.pipeline import pipeline_apply
mesh = jax.make_mesh((4,), ("stage",),
                     axis_types=(jax.sharding.AxisType.Auto,))
env = MeshEnv(mesh=mesh)
rng = np.random.default_rng(4)
S, B, D = 4, 8, 16
ws = jnp.asarray(rng.normal(size=(S, D, D)) * 0.3, jnp.float32)
x = jnp.asarray(rng.normal(size=(B, D)), jnp.float32)
layer = lambda w, h: jnp.tanh(h @ w)
with mesh:
    y = pipeline_apply(layer, ws, x, env=env, axis="stage", n_micro=4)
ref = x
for i in range(S):
    ref = layer(ws[i], ref)
np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=2e-5, atol=2e-5)
print("pipeline OK")
""", devices=4)


def test_mlstm_seq_sharded_matches_single():
    run_sub(COMMON + """
from repro.models.recurrent import mlstm_seq
rng = np.random.default_rng(5)
B, S, H, hd = 4, 32, 2, 8
mk = lambda shape: jnp.asarray(rng.normal(size=shape), jnp.float32)
q, k, v = mk((B,S,H,hd)), mk((B,S,H,hd)), mk((B,S,H,hd))
i_r, f_r = mk((B,S,H)), mk((B,S,H)) + 2.0
with mesh:
    out = mlstm_seq(q, k, v, i_r, f_r, env=env)
env1 = MeshEnv(mesh=jax.make_mesh((1, 1), ("data", "model"),
               axis_types=(jax.sharding.AxisType.Auto,) * 2))
ref = mlstm_seq(q, k, v, i_r, f_r, env=env1)
np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=3e-4, atol=3e-4)
print("mlstm OK")
""")


def test_rglru_seq_sharded_matches_single():
    run_sub(COMMON + """
from repro.models.recurrent import rglru_seq
rng = np.random.default_rng(6)
B, S, D = 4, 32, 16
mk = lambda shape: jnp.asarray(rng.normal(size=shape), jnp.float32)
x = mk((B,S,D))
wrg, wig = mk((D,D))*0.3, mk((D,D))*0.3
brg, big = mk((D,)), mk((D,))
cw, cb = mk((4,D))*0.3, mk((D,))
lam = jnp.full((D,), 0.7)
with mesh:
    out = rglru_seq(x, wrg, brg, wig, big, cw, cb, lam, env=env)
env1 = MeshEnv(mesh=jax.make_mesh((1, 1), ("data", "model"),
               axis_types=(jax.sharding.AxisType.Auto,) * 2))
ref = rglru_seq(x, wrg, brg, wig, big, cw, cb, lam, env=env1)
np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=3e-4, atol=3e-4)
print("rglru OK")
""")


def test_moe_dispatch_balanced_routing_exact():
    run_sub(COMMON + """
from repro.configs import ARCHS
from repro.models.moe import moe_init, moe_dispatch
import dataclasses
cfg = dataclasses.replace(ARCHS["qwen3-moe-235b-a22b"].reduced(),
                          n_experts=4, moe_top_k=2, capacity_factor=8.0)
p = moe_init(cfg, jax.random.PRNGKey(0))
rng = np.random.default_rng(7)
x = jnp.asarray(rng.normal(size=(4, 8, cfg.d_model)) * 0.1, jnp.float32)
with mesh:
    y, aux = moe_dispatch(cfg, p, x, env=env)
env1 = MeshEnv(mesh=jax.make_mesh((1, 1), ("data", "model"),
               axis_types=(jax.sharding.AxisType.Auto,) * 2))
y1, aux1 = moe_dispatch(cfg, p, x, env=env1)
# generous capacity -> no drops -> distributed == single-device
np.testing.assert_allclose(np.asarray(y), np.asarray(y1), rtol=3e-4, atol=3e-4)
print("moe OK", float(aux), float(aux1))
""")
