"""Session plan cache: repeated queries skip search, any store
mutation invalidates, fingerprints are value identities.

The pure cache/store interplay is property-tested (hypothesis, when
available) against random op sequences; session-level behavior
(plan_cached on reports, search skipping) uses example tests that run
everywhere.
"""
import numpy as np
import pytest

from repro.api import Interval, MLegoSession, PlanCache, QuerySpec
from repro.configs.lda_default import LDAConfig
from repro.core.search import SearchResult
from repro.core.store import ModelStore
from repro.data.corpus import make_corpus, train_test_split

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:         # optional dev dep (see ci.yml)
    HAVE_HYPOTHESIS = False

CFG = LDAConfig(n_topics=6, vocab_size=150, alpha=0.5, eta=0.05,
                max_iters=6, e_step_iters=5, gibbs_sweeps=6)
RNG = np.random.default_rng(11)


@pytest.fixture(scope="module")
def train():
    corpus, _ = make_corpus(300, CFG.vocab_size, CFG.n_topics,
                            mean_doc_len=30, seed=3)
    train, _ = train_test_split(corpus, test_frac=0.1, seed=1)
    return train


def _covered_session(train, edges=(0.0, 100.0, 200.0, 300.0)):
    """Session whose store fully tiles [0, 300) — full-coverage queries
    train nothing, so submits leave the store untouched."""
    store = ModelStore()
    for lo, hi in zip(edges, edges[1:]):
        theta = {"lam": RNG.gamma(1.0, 1.0,
                                  (CFG.n_topics, CFG.vocab_size))
                 .astype(np.float32)}
        store.add(Interval(lo, hi), 50, 500, "vb", theta)
    return MLegoSession(train, CFG, store=store, kind="vb")


# ---------------------------------------------------------------------------
# session-level behavior (acceptance criteria)
# ---------------------------------------------------------------------------

def test_second_identical_submit_is_a_cache_hit(train):
    sess = _covered_session(train)
    spec = QuerySpec(sigma=Interval(0.0, 300.0), alpha=1.0)
    first = sess.submit(spec)
    assert not first.plan_cached
    assert sess.plan_cache.hits == 0
    second = sess.submit(spec)
    assert second.plan_cached, "unchanged store must serve the cached plan"
    assert sess.plan_cache.hits == 1
    assert second.model_ids == first.model_ids
    np.testing.assert_array_equal(first.beta, second.beta)


def test_store_mutation_invalidates_plan_cache(train):
    sess = _covered_session(train)
    spec = QuerySpec(sigma=Interval(0.0, 300.0), alpha=1.0)
    sess.submit(spec)
    assert len(sess.plan_cache) == 1
    # any mutation — here an add outside the query — must invalidate
    sess.store.add(Interval(400.0, 500.0), 10, 100, "vb",
                   {"lam": np.ones((CFG.n_topics, CFG.vocab_size),
                                   np.float32)})
    assert len(sess.plan_cache) == 0
    rep = sess.submit(spec)
    assert not rep.plan_cached


def test_store_remove_invalidates_plan_cache(train):
    sess = _covered_session(train)
    spec = QuerySpec(sigma=Interval(0.0, 300.0), alpha=1.0)
    rep = sess.submit(spec)
    sess.store.remove(rep.model_ids[0])
    rep2 = sess.submit(spec)
    assert not rep2.plan_cached
    assert rep.model_ids[0] not in rep2.model_ids


def test_persisting_gap_training_invalidates_own_cache_entry(train):
    """A submit that grows the store cannot be followed by a stale hit:
    the fresh models change the plan space."""
    sess = _covered_session(train, edges=(0.0, 150.0))
    spec = QuerySpec(sigma=Interval(0.0, 300.0), alpha=0.0)
    first = sess.submit(spec)
    assert first.n_trained_tokens > 0          # [150, 300) trained + persisted
    second = sess.submit(spec)
    assert not second.plan_cached, "store changed mid-submit"
    # the re-search sees the persisted gap model: nothing to train now
    assert second.n_trained_tokens == 0


def test_volatile_submit_keeps_cache_warm(train):
    sess = _covered_session(train, edges=(0.0, 150.0))
    spec = QuerySpec(sigma=Interval(0.0, 300.0), alpha=0.0,
                     materialize="volatile")
    sess.submit(spec)
    second = sess.submit(spec)
    assert second.plan_cached, "volatile queries leave the store unchanged"
    assert second.n_trained_tokens > 0, "the gap is still retrained"


def test_union_components_cache_independently(train):
    sess = _covered_session(train)
    union = QuerySpec(sigma=[Interval(0.0, 100.0), Interval(200.0, 300.0)],
                      alpha=1.0)
    sess.submit(union)
    assert len(sess.plan_cache) == 2           # one entry per component
    # a single-interval query on one component reuses its entry
    rep = sess.submit(QuerySpec(sigma=Interval(0.0, 100.0), alpha=1.0))
    assert rep.plan_cached


def test_distinct_specs_do_not_collide(train):
    sess = _covered_session(train)
    a = QuerySpec(sigma=Interval(0.0, 300.0), alpha=1.0)
    sess.submit(a)
    for other in (QuerySpec(sigma=Interval(0.0, 200.0), alpha=1.0),
                  QuerySpec(sigma=Interval(0.0, 300.0), alpha=0.3),
                  QuerySpec(sigma=Interval(0.0, 300.0), alpha=1.0,
                            method="psoa")):
        rep = sess.submit(other)
        assert not rep.plan_cached, other


def test_store_swap_rebinds_plan_cache(train):
    sess = _covered_session(train)
    spec = QuerySpec(sigma=Interval(0.0, 300.0), alpha=1.0)
    sess.submit(spec)
    assert len(sess.plan_cache) == 1
    sess.store = _covered_session(train).store      # fresh store object
    assert len(sess.plan_cache) == 0
    # mutations of the *new* store keep invalidating
    sess.submit(spec)
    assert len(sess.plan_cache) == 1
    sess.store.add(Interval(400.0, 500.0), 10, 100, "vb",
                   {"lam": np.ones((CFG.n_topics, CFG.vocab_size),
                                   np.float32)})
    assert len(sess.plan_cache) == 0


# ---------------------------------------------------------------------------
# batch-level entries (submit_many memoizes the whole Alg. 4 result)
# ---------------------------------------------------------------------------

def _batch_specs():
    return [QuerySpec(sigma=Interval(0.0, 300.0), alpha=0.0),
            QuerySpec(sigma=Interval(100.0, 300.0), alpha=0.0)]


def test_repeated_identical_batch_is_a_cache_hit(train):
    sess = _covered_session(train)
    first = sess.submit_many(_batch_specs())
    assert not first.plan_cached
    second = sess.submit_many(_batch_specs())
    assert second.plan_cached, "repeated batch must skip Alg. 4"
    assert second.opt is first.opt, "the memoized BatchResult is served"
    for a, b in zip(first.reports, second.reports):
        np.testing.assert_array_equal(a.beta, b.beta)


def test_batch_cache_invalidates_on_store_mutation(train):
    sess = _covered_session(train)
    sess.submit_many(_batch_specs())
    sess.store.add(Interval(400.0, 500.0), 10, 100, "vb",
                   {"lam": np.ones((CFG.n_topics, CFG.vocab_size),
                                   np.float32)})
    rerun = sess.submit_many(_batch_specs())
    assert not rerun.plan_cached, "store mutation must drop batch entries"


def test_different_batches_do_not_collide(train):
    sess = _covered_session(train)
    sess.submit_many(_batch_specs())
    # same sigmas, different grouping: two specs vs one union spec
    union = sess.submit_many([QuerySpec(
        sigma=[Interval(0.0, 100.0), Interval(200.0, 300.0)], alpha=0.0)])
    assert not union.plan_cached
    reordered = sess.submit_many(list(reversed(_batch_specs())))
    assert not reordered.plan_cached
    assert sess.submit_many(_batch_specs()).plan_cached


def test_gap_training_batch_invalidates_own_entry(train):
    """A batch that persists gap models mutates the store mid-run; the
    next identical batch must re-plan against the grown model set."""
    sess = _covered_session(train, edges=(0.0, 150.0))
    specs = [QuerySpec(sigma=Interval(0.0, 300.0), alpha=0.0)]
    first = sess.submit_many(specs)
    assert first.materialized, "the [150, 300) gap was trained + persisted"
    second = sess.submit_many(specs)
    assert not second.plan_cached
    assert not second.materialized, "re-plan fetches the persisted model"
    third = sess.submit_many(specs)
    assert third.plan_cached


# ---------------------------------------------------------------------------
# cache/store interplay (pure; property-tested under hypothesis)
# ---------------------------------------------------------------------------

def _tiny_theta():
    return {"lam": np.ones((2, 4), np.float32)}


def _fake_result(tag):
    return SearchResult(plan=(), score=float(tag), alpha=0.0)


def _run_ops(ops):
    """Replay (op, arg) sequences against a bound PlanCache; assert the
    two invariants: (1) immediately after any store mutation the cache
    is empty; (2) a lookup between a put and the next mutation returns
    exactly the cached result."""
    store = ModelStore()
    cache = PlanCache(max_entries=64)
    cache.bind_store(store)
    live_ids = []
    cached_keys = {}
    tag = 0
    for op, arg in ops:
        if op == "add":
            m = store.add(Interval(float(arg), float(arg) + 1.0), 1, 10,
                          "vb", _tiny_theta())
            live_ids.append(m.model_id)
            assert len(cache) == 0, "add must clear the cache"
            cached_keys.clear()
        elif op == "remove" and live_ids:
            store.remove(live_ids.pop(arg % len(live_ids)))
            assert len(cache) == 0, "remove must clear the cache"
            cached_keys.clear()
        elif op == "put":
            tag += 1
            key = ("q", arg, PlanCache.fingerprint(store.models()))
            res = _fake_result(tag)
            cache.put(key, res)
            cached_keys[key] = res
        elif op == "get":
            key = ("q", arg, PlanCache.fingerprint(store.models()))
            got = cache.get(key)
            if key in cached_keys:
                assert got is cached_keys[key], "stale or missing hit"
            else:
                assert got is None, "hit for a never-cached key"


def test_cache_invalidation_example_sequences():
    _run_ops([("put", 0), ("get", 0), ("add", 1), ("get", 0),
              ("put", 0), ("put", 1), ("get", 1), ("remove", 0),
              ("get", 1), ("put", 2), ("get", 2), ("get", 0)])
    _run_ops([("add", 0), ("add", 5), ("put", 3), ("get", 3),
              ("get", 4), ("remove", 1), ("put", 3), ("get", 3)])


def test_fingerprint_is_value_identity():
    store_a, store_b = ModelStore(), ModelStore()
    for s in (store_a, store_b):
        s.add(Interval(0.0, 1.0), 1, 10, "vb", _tiny_theta())
        s.add(Interval(2.0, 3.0), 1, 10, "vb", _tiny_theta())
    assert PlanCache.fingerprint(store_a.models()) == \
        PlanCache.fingerprint(store_b.models())
    store_b.add(Interval(4.0, 5.0), 1, 10, "vb", _tiny_theta())
    assert PlanCache.fingerprint(store_a.models()) != \
        PlanCache.fingerprint(store_b.models())
    # order-insensitive
    assert PlanCache.fingerprint(list(reversed(store_a.models()))) == \
        PlanCache.fingerprint(store_a.models())


def test_cache_lru_bound():
    cache = PlanCache(max_entries=4)
    for i in range(10):
        cache.put(("k", i), _fake_result(i))
    assert len(cache) == 4
    assert cache.get(("k", 0)) is None
    assert cache.get(("k", 9)) is not None


if HAVE_HYPOTHESIS:
    OPS = st.lists(
        st.tuples(st.sampled_from(["add", "remove", "put", "get"]),
                  st.integers(0, 5)),
        min_size=1, max_size=30)

    @settings(max_examples=50, deadline=None)
    @given(OPS)
    def test_cache_invalidation_property(ops):
        """Any interleaving of store mutations and cache traffic keeps
        the cache consistent: mutations clear it, lookups never serve
        an entry across a mutation."""
        _run_ops(ops)
