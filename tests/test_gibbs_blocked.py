"""Doc-blocked CGS: kernel-vs-reference exactness, statistical parity
of the blocked sampler against the exact token scan, and the device
backend's Gibbs gap-training route (train_device_ms, LRU warm
inserts)."""
import numpy as np
import jax
import pytest

from repro.api import DeviceBackend, Interval, MLegoSession, QuerySpec
from repro.configs.lda_default import LDAConfig
from repro.core.gibbs import blocked_layout, cgs_fit, cgs_fit_blocked
from repro.core.lda import (
    greedy_topic_overlap,
    log_predictive_probability,
    topics_from_gs,
)
from repro.data.corpus import doc_term_matrix, make_corpus, train_test_split

CFG = LDAConfig(n_topics=8, vocab_size=300, alpha=0.5, eta=0.05,
                gibbs_sweeps=10)


@pytest.fixture(scope="module")
def corpus():
    c, _ = make_corpus(240, CFG.vocab_size, CFG.n_topics,
                       mean_doc_len=40, seed=0)
    return c


@pytest.fixture(scope="module")
def split(corpus):
    return train_test_split(corpus, test_frac=0.15, seed=1)


# ---------------------------------------------------------------------------
# layout
# ---------------------------------------------------------------------------

def test_blocked_layout_partitions_all_tokens(corpus):
    words, ldoc, mask = blocked_layout(corpus.tokens, corpus.doc_ids,
                                       corpus.n_docs, block_docs=32)
    assert int(mask.sum()) == corpus.n_tokens
    assert words.shape == ldoc.shape == mask.shape
    assert words.shape[0] == -(-corpus.n_docs // 32)
    assert (ldoc < 32).all() and (ldoc >= 0).all()
    # every real token survives the packing with its word id
    np.testing.assert_array_equal(
        np.sort(words[mask > 0]), np.sort(corpus.tokens))


def test_blocked_layout_single_block(corpus):
    words, ldoc, mask = blocked_layout(corpus.tokens, corpus.doc_ids,
                                       corpus.n_docs,
                                       block_docs=corpus.n_docs + 10)
    assert words.shape[0] == 1
    assert int(mask.sum()) == corpus.n_tokens


# ---------------------------------------------------------------------------
# kernel vs jnp reference: identical math, identical outputs
# ---------------------------------------------------------------------------

def test_kernel_matches_reference_exactly(corpus):
    key = jax.random.PRNGKey(3)
    ref = cgs_fit_blocked(corpus.tokens, corpus.doc_ids, CFG, key,
                          block_docs=32, use_kernel=False)
    ker = cgs_fit_blocked(corpus.tokens, corpus.doc_ids, CFG, key,
                          block_docs=32, use_kernel=True, interpret=True)
    np.testing.assert_array_equal(ref, ker)
    assert ref.sum() == corpus.n_tokens


def test_kernel_matches_reference_with_global_prior(corpus):
    """The DSGS step (Eq. 8): sampling against a fixed global N_kv."""
    rng = np.random.default_rng(5)
    gnkv = rng.gamma(1.0, 2.0, (CFG.n_topics, CFG.vocab_size)) \
        .astype(np.float32)
    key = jax.random.PRNGKey(4)
    ref = cgs_fit_blocked(corpus.tokens, corpus.doc_ids, CFG, key,
                          global_nkv=gnkv, block_docs=64, use_kernel=False)
    ker = cgs_fit_blocked(corpus.tokens, corpus.doc_ids, CFG, key,
                          global_nkv=gnkv, block_docs=64, use_kernel=True,
                          interpret=True)
    np.testing.assert_array_equal(ref, ker)


def test_empty_partition_returns_zeros():
    out = cgs_fit_blocked(np.empty(0, np.int32), np.empty(0, np.int32),
                          CFG, jax.random.PRNGKey(0))
    assert out.shape == (CFG.n_topics, CFG.vocab_size)
    assert (out == 0).all()


def test_unsorted_doc_ids_match_sorted(corpus):
    """cgs_fit accepts any token order; the blocked path must too
    (it re-sorts to the CSR layout internally)."""
    rng = np.random.default_rng(0)
    perm = rng.permutation(corpus.n_tokens)
    key = jax.random.PRNGKey(2)
    sorted_nkv = cgs_fit_blocked(corpus.tokens, corpus.doc_ids, CFG, key,
                                 block_docs=32)
    shuffled_nkv = cgs_fit_blocked(corpus.tokens[perm],
                                   corpus.doc_ids[perm], CFG, key,
                                   block_docs=32)
    assert shuffled_nkv.sum() == corpus.n_tokens
    # stable doc-sort of an intra-doc shuffle is not the identity
    # permutation, so counts only match statistically — but every
    # token must land somewhere and the layout must not corrupt
    assert shuffled_nkv.min() >= 0
    np.testing.assert_array_equal(shuffled_nkv.sum(axis=0).astype(int),
                                  sorted_nkv.sum(axis=0).astype(int))


def test_counts_conserved_and_nonnegative(corpus):
    nkv = cgs_fit_blocked(corpus.tokens, corpus.doc_ids, CFG,
                          jax.random.PRNGKey(9), block_docs=48)
    assert nkv.min() >= 0
    assert nkv.sum() == corpus.n_tokens


# ---------------------------------------------------------------------------
# statistical parity: blocked vs exact scan (fixed seeds, tolerance
# calibrated against exact-vs-exact seed noise — different seeds of the
# *exact* sampler show ~0.59 matched top-word overlap and ~0.01 lpp
# spread on this config; the blocked sampler must land in that band)
# ---------------------------------------------------------------------------

def test_blocked_statistically_matches_exact(split):
    train, test = split
    x_test = doc_term_matrix(test)
    key = jax.random.PRNGKey(0)
    nkv_e = cgs_fit(train.tokens, train.doc_ids, CFG, key)
    nkv_b = cgs_fit_blocked(train.tokens, train.doc_ids, CFG, key,
                            block_docs=32)
    beta_e = topics_from_gs(nkv_e, CFG.eta)
    beta_b = topics_from_gs(nkv_b, CFG.eta)
    lpp_e = log_predictive_probability(beta_e, x_test)
    lpp_b = log_predictive_probability(beta_b, x_test)
    assert abs(lpp_b - lpp_e) < 0.15, \
        f"blocked perplexity drifted: {lpp_b:.4f} vs exact {lpp_e:.4f}"
    assert greedy_topic_overlap(beta_e, beta_b) >= 0.35, \
        "blocked topics diverged beyond seed noise"


# ---------------------------------------------------------------------------
# device backend route (train_gap for gs kind)
# ---------------------------------------------------------------------------

def _sessions(train):
    host = MLegoSession(train, CFG, kind="gs", backend="host", seed=0)
    dev = MLegoSession(train, CFG, kind="gs", backend="device", seed=0)
    return host, dev


def test_device_train_gap_parity_for_gs(split):
    """Uncovered gs query: host trains the exact scan, device the
    blocked kernel route — answers must agree statistically and both
    must be proper topic matrices."""
    train, test = split
    x_test = doc_term_matrix(test)
    host, dev = _sessions(train)
    spec = QuerySpec(sigma=Interval(0.0, 150.0))
    rh, rd = host.submit(spec), dev.submit(spec)
    for r in (rh, rd):
        assert r.n_trained_tokens > 0
        assert np.isfinite(r.beta).all()
        np.testing.assert_allclose(r.beta.sum(1), 1.0, rtol=1e-4)
    lpp_h = log_predictive_probability(rh.beta, x_test)
    lpp_d = log_predictive_probability(rd.beta, x_test)
    assert abs(lpp_h - lpp_d) < 0.3
    assert rh.train_device_ms == 0.0, "host path must not claim kernel time"
    assert rd.train_device_ms > 0.0
    assert rd.backend == "device" and rh.backend == "host"
    assert dev.backend.stats.gap_device_trains == 1


def test_device_gap_model_warms_the_lru(split):
    train, _ = split
    _, dev = _sessions(train)
    rep = dev.submit(QuerySpec(sigma=Interval(0.0, 150.0)))
    assert len(rep.materialized) == 1
    mid = rep.materialized[0].model_id
    assert mid in dev.backend.cache, \
        "fresh gap model must be warm-inserted into the device cache"
    assert dev.backend.stats.train_uploads == 1
    # and the merge that followed read it back as a hit, not a re-upload
    assert dev.backend.stats.cache_hits >= 1


def test_volatile_gap_model_does_not_warm_the_lru(split):
    train, _ = split
    _, dev = _sessions(train)
    rep = dev.submit(QuerySpec(sigma=Interval(0.0, 150.0),
                               materialize="volatile"))
    assert [m.model_id for m in rep.materialized] == [-1]
    assert dev.backend.stats.train_uploads == 0
    assert len(dev.backend.cache) == 0


def test_kernel_gibbs_opt_out_uses_host_trainer(split):
    train, _ = split
    backend = DeviceBackend(kernel_gibbs=False)
    dev = MLegoSession(train, CFG, kind="gs", backend=backend, seed=0)
    rep = dev.submit(QuerySpec(sigma=Interval(0.0, 150.0)))
    assert np.isfinite(rep.beta).all()
    assert backend.stats.gap_device_trains == 0
    assert rep.train_device_ms == 0.0


def test_train_timings_feed_backend_keyed_kappa(split):
    """A calibrated session observes device gap training under the
    device key, so the planner prices device training separately."""
    train, _ = split
    dev = MLegoSession(train, CFG, kind="gs", backend="device",
                       cost="calibrated", seed=0)
    dev.submit(QuerySpec(sigma=Interval(0.0, 150.0)))
    cal = dev.cost.calibration
    assert "device" in cal.train_obs and cal.train_obs["device"]
    assert "host" not in cal.train_obs
