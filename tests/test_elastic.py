"""Elastic repartition + failure recovery over the model store."""
import numpy as np
import pytest

from repro.configs.lda_default import LDAConfig
from repro.core.lda import MaterializedModel
from repro.core.merge import merge_vb
from repro.core.plans import Interval
from repro.core.store import ModelStore
from repro.distributed.elastic import (
    partition_ranges,
    plan_repartition,
    apply_repartition,
    recover_failed,
)

CFG = LDAConfig(n_topics=4, vocab_size=32, eta=0.05)


def _store(rng, ranges):
    store = ModelStore()
    for lo, hi in ranges:
        store.add(Interval(lo, hi), 10, 100, "vb",
                  {"lam": rng.gamma(1.0, 1.0, (4, 32)).astype(np.float32)})
    return store


def test_partition_ranges_tile_universe():
    spans = partition_ranges(Interval(0.0, 100.0), 4)
    assert len(spans) == 4
    assert spans[0].lo == 0.0 and spans[-1].hi == 100.0
    for a, b in zip(spans, spans[1:]):
        assert a.hi == b.lo


def test_repartition_covers_everything():
    rng = np.random.default_rng(0)
    store = _store(rng, [(0, 20), (20, 45), (50, 75), (80, 100)])
    parts = plan_repartition(store, Interval(0.0, 100.0), 2)
    for part in parts:
        covered = [store.get(m).o for m in part.model_ids]
        total = sum(iv.length for iv in covered) + \
            sum(g.length for g in part.missing)
        assert total == pytest.approx(part.span.length)


def test_apply_repartition_merges_exactly():
    rng = np.random.default_rng(1)
    store = _store(rng, [(0, 25), (25, 50), (50, 75), (75, 100)])
    parts = plan_repartition(store, Interval(0.0, 100.0), 2)
    trained = []

    def train_fn(lo, hi):
        trained.append((lo, hi))
        m = MaterializedModel(1000 + len(trained), Interval(lo, hi), 5, 50,
                              "vb", {"lam": np.ones((4, 32), np.float32)})
        return m

    out = apply_repartition(parts, store, CFG, train_fn)
    assert not trained, "fully covered universe must not retrain"
    assert set(out) == {0, 1}
    # worker 0 model == direct merge of its two range models
    w0_models = [store.get(mid) for mid in parts[0].model_ids]
    np.testing.assert_allclose(out[0].theta["lam"],
                               merge_vb(w0_models, CFG), rtol=1e-6)


def test_recover_failed_trains_only_lost():
    rng = np.random.default_rng(2)
    store = _store(rng, [(0, 30), (60, 100)])
    trained = []

    def train_fn(lo, hi):
        trained.append((lo, hi))
        return MaterializedModel(-1, Interval(lo, hi), 1, 10, "vb",
                                 {"lam": np.ones((4, 32), np.float32)})

    fresh = recover_failed(store, [Interval(0.0, 100.0)], train_fn)
    assert trained == [(30.0, 60.0)]
    assert len(fresh) == 1
