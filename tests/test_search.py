"""Plan-search correctness: PSOA finds the NAI optimum (Def. 2) with a
fraction of the evaluations; PSOA++/GRA agree in the coverage regime."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dev dep (see ci.yml)
from hypothesis import given, settings, strategies as st

from repro.core.cost import CostModel, plan_stats
from repro.core.plans import Interval
from repro.core.search import gra_search, nai_search, psoa_search
from tests.conftest import build_store


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from([0.0, 0.2, 0.5, 0.8, 0.99]),
       st.integers(4, 12))
def test_psoa_matches_nai(small_index_seed, alpha, n_models):
    # hypothesis can't take fixtures in @given; rebuild the index inline
    from repro.configs.lda_default import LDAConfig
    from repro.data.corpus import DataIndex, make_corpus
    corpus, _ = make_corpus(300, 64, 4, mean_doc_len=12, seed=11)
    index = DataIndex(corpus)
    store = build_store(index, n_models=n_models, seed=small_index_seed,
                        span=(0.0, 300.0), k=4, v=64)
    cost = CostModel(max_iters=10, n_topics=4)
    q = Interval(10.0, 280.0)
    nai = nai_search(store.models(), q, index, cost, alpha)
    psoa = psoa_search(store.models(), q, index, cost, alpha,
                       use_plus=False)
    assert psoa.score == pytest.approx(nai.score, rel=1e-9), (
        alpha, psoa.model_ids, nai.model_ids)


def test_psoa_scores_fewer_plans_than_nai(small_index):
    store = build_store(small_index, n_models=14, seed=5)
    cost = CostModel(max_iters=10, n_topics=8)
    q = Interval(0.0, 390.0)
    nai = nai_search(store.models(), q, small_index, cost, 0.3)
    psoa = psoa_search(store.models(), q, small_index, cost, 0.3)
    assert psoa.score == pytest.approx(nai.score, rel=1e-9)
    assert psoa.n_scored < nai.n_scored


def test_psoa_plus_plus_coverage_regime(small_index, cost_model):
    """Below the Thm. 3/4 critical point, PSOA++ = max coverage = GRA."""
    store = build_store(small_index, n_models=8, seed=2)
    q = Interval(0.0, 390.0)
    plus = psoa_search(store.models(), q, small_index, cost_model, 0.0,
                       use_plus=True)
    gra = gra_search(store.models(), q, small_index, cost_model)
    # same uncovered data (the objective in this regime), tolerance = the
    # merge-cost slack the theorems allow
    _, unc_plus = plan_stats(plus.plan, q, small_index)
    _, unc_gra = plan_stats(gra.plan, q, small_index)
    assert unc_plus == unc_gra
    if plus.method == "PSOA++":
        slack = cost_model.t_merge * max(len(plus.plan), len(gra.plan), 1)
        denom = max(cost_model.c_train(
            small_index.tokens_in(q.lo, q.hi)), 1e-30)
        assert abs(plus.score - gra.score) <= slack / denom + 1e-12


def test_alpha_one_maximizes_reuse(small_index, cost_model):
    store = build_store(small_index, n_models=10, seed=3)
    q = Interval(0.0, 390.0)
    r = psoa_search(store.models(), q, small_index, cost_model, 1.0)
    # Alg. 3 line 5: the a=1 plan has the most models among RL plans
    from repro.core.plans import rl_plans, usable
    cand = [m for m in usable(store.models(), q)
            if small_index.tokens_in(m.o.lo, m.o.hi) > 0]
    width = max(len(p) for p in rl_plans(cand, q))
    assert len(r.plan) == width


def test_empty_store_trains_from_scratch(small_index, cost_model):
    from repro.core.store import ModelStore
    q = Interval(0.0, 100.0)
    r = psoa_search(ModelStore().models(), q, small_index, cost_model, 0.5)
    assert r.plan == ()
    assert r.score > 0


def test_score_constraint_positive(small_index, cost_model):
    """Def. 2: sc(p) > 0 — a full-coverage single model scores 0 at
    alpha=1 and must not be returned there."""
    store = build_store(small_index, n_models=6, seed=4)
    q = Interval(0.0, 390.0)
    for alpha in (0.0, 0.5):
        r = psoa_search(store.models(), q, small_index, cost_model, alpha)
        assert r.score > 0
