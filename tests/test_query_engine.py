"""End-to-end analytic queries: merged models vs from-scratch (the
paper's DP metric), store growth, batch path.

Migrated from the retired ``QueryEngine`` facade to the canonical
``MLegoSession`` API; a single shim test pins the deprecation alias.
"""
import numpy as np
import pytest

import jax

from repro.api import MLegoSession, QuerySpec
from repro.configs.lda_default import LDAConfig
from repro.core.lda import log_predictive_probability
from repro.core.plans import Interval
from repro.core.store import ModelStore
from repro.core.vb import vb_fit
from repro.data.corpus import doc_term_matrix, make_corpus, train_test_split

CFG = LDAConfig(n_topics=6, vocab_size=150, alpha=0.5, eta=0.05,
                max_iters=12, e_step_iters=8, gibbs_sweeps=8)


@pytest.fixture(scope="module")
def world():
    corpus, beta = make_corpus(350, CFG.vocab_size, CFG.n_topics,
                               mean_doc_len=40, seed=3)
    train, test = train_test_split(corpus, test_frac=0.15, seed=1)
    return train, test, beta


def _session(train, kind="vb"):
    return MLegoSession(train, CFG, kind=kind, seed=0)


@pytest.mark.parametrize("kind", ["vb", "gs"])
def test_query_merge_close_to_scratch(world, kind):
    train, test, _ = world
    sess = _session(train, kind)
    # materialize two halves, then query the union -> pure merge plan
    sess.train_range(0.0, 170.0)
    sess.train_range(170.0, 350.0)
    rep = sess.submit(QuerySpec(sigma=Interval(0.0, 350.0), alpha=0.5))
    assert rep.n_trained_tokens == 0, "full coverage -> no training"
    assert rep.n_merged == 2

    x_test = doc_term_matrix(test)
    lpp_merged = log_predictive_probability(rep.beta, x_test)

    # from-scratch reference on the same range
    scratch = _session(train, kind).submit(
        QuerySpec(sigma=Interval(0.0, 350.0), alpha=0.5))
    lpp_scratch = log_predictive_probability(scratch.beta, x_test)

    dp = abs(lpp_scratch - lpp_merged)
    # the paper's observed DP is small; generous envelope for tiny corpora
    assert dp < 0.35, (lpp_merged, lpp_scratch)
    assert np.isfinite(rep.beta).all()
    np.testing.assert_allclose(rep.beta.sum(1), 1.0, rtol=1e-4)


def test_store_grows_with_queries(world):
    train, _, _ = world
    sess = _session(train)
    assert len(sess.store) == 0
    sess.submit(QuerySpec(sigma=Interval(0.0, 100.0), alpha=0.0))
    n1 = len(sess.store)
    assert n1 >= 1
    # second query over a covered range reuses, trains only the gap
    rep = sess.submit(QuerySpec(sigma=Interval(0.0, 150.0), alpha=0.0))
    assert any(m.o == Interval(0.0, 100.0) for m in rep.plan.plan) or \
        rep.n_trained_tokens > 0


def test_batch_execution_consistent(world):
    train, test, _ = world
    sess = _session(train)
    sess.train_range(0.0, 120.0)
    queries = [Interval(0.0, 200.0), Interval(100.0, 300.0)]
    br = sess.submit_many([QuerySpec(sigma=q) for q in queries])
    assert len(br) == 2
    assert br.opt.benefit >= 0.0
    x_test = doc_term_matrix(test)
    for r in br:
        assert np.isfinite(r.beta).all()
        lpp = log_predictive_probability(r.beta, x_test)
        assert lpp > -np.log(CFG.vocab_size) * 1.5   # sanity: beats uniform-ish


def test_query_engine_alias_warns_and_delegates(world):
    """The retired facade stays one release as a deprecation shim: it
    warns at construction, is-a MLegoSession, and execute/execute_batch
    route through submit/submit_many."""
    from repro.core.query import QueryEngine

    train, _, _ = world
    with pytest.warns(DeprecationWarning, match="QueryEngine is deprecated"):
        engine = QueryEngine(train, ModelStore(), CFG, kind="vb", seed=0)
    assert isinstance(engine, MLegoSession)
    engine.train_range(0.0, 170.0)
    res = engine.execute(Interval(0.0, 350.0), alpha=0.5)
    ref = _session(train)
    ref.train_range(0.0, 170.0)
    rep = ref.submit(QuerySpec(sigma=Interval(0.0, 350.0), alpha=0.5))
    np.testing.assert_array_equal(res.beta, rep.beta)
    assert res.n_trained_tokens == rep.n_trained_tokens

    results, opt = engine.execute_batch([Interval(0.0, 200.0)])
    assert len(results) == 1
    assert opt.benefit >= 0.0
    assert engine.last_batch_report is not None
    assert engine.last_batch_report.reports[0] is results[0]


def test_lda_recovers_topics_better_than_random(world):
    """vb_fit on synthetic LDA data beats a random topic matrix on lpp."""
    train, test, beta_true = world
    x = doc_term_matrix(train)
    lam = np.asarray(vb_fit(x, jax.random.PRNGKey(0), CFG))
    beta_hat = lam / lam.sum(1, keepdims=True)
    x_test = doc_term_matrix(test)
    lpp_fit = log_predictive_probability(beta_hat, x_test)
    rng = np.random.default_rng(0)
    beta_rand = rng.dirichlet(np.full(CFG.vocab_size, 0.5), CFG.n_topics)
    lpp_rand = log_predictive_probability(beta_rand, x_test)
    assert lpp_fit > lpp_rand + 0.3, (lpp_fit, lpp_rand)
