"""End-to-end analytic queries: merged models vs from-scratch (the
paper's DP metric), store growth, batch path."""
import numpy as np
import pytest

import jax

from repro.configs.lda_default import LDAConfig
from repro.core.lda import log_predictive_probability
from repro.core.plans import Interval
from repro.core.query import QueryEngine
from repro.core.store import ModelStore
from repro.core.vb import vb_fit
from repro.data.corpus import doc_term_matrix, make_corpus, train_test_split

CFG = LDAConfig(n_topics=6, vocab_size=150, alpha=0.5, eta=0.05,
                max_iters=12, e_step_iters=8, gibbs_sweeps=8)


@pytest.fixture(scope="module")
def world():
    corpus, beta = make_corpus(350, CFG.vocab_size, CFG.n_topics,
                               mean_doc_len=40, seed=3)
    train, test = train_test_split(corpus, test_frac=0.15, seed=1)
    return train, test, beta


@pytest.mark.parametrize("kind", ["vb", "gs"])
def test_query_merge_close_to_scratch(world, kind):
    train, test, _ = world
    engine = QueryEngine(train, ModelStore(), CFG, kind=kind, seed=0)
    # materialize two halves, then query the union -> pure merge plan
    engine.train_range(0.0, 170.0)
    engine.train_range(170.0, 350.0)
    res = engine.execute(Interval(0.0, 350.0), alpha=0.5)
    assert res.n_trained_tokens == 0, "full coverage -> no training"
    assert res.n_merged == 2

    x_test = doc_term_matrix(test)
    lpp_merged = log_predictive_probability(res.beta, x_test)

    # from-scratch reference on the same range
    eng2 = QueryEngine(train, ModelStore(), CFG, kind=kind, seed=0)
    scratch = eng2.execute(Interval(0.0, 350.0), alpha=0.5)
    lpp_scratch = log_predictive_probability(scratch.beta, x_test)

    dp = abs(lpp_scratch - lpp_merged)
    # the paper's observed DP is small; generous envelope for tiny corpora
    assert dp < 0.35, (lpp_merged, lpp_scratch)
    assert np.isfinite(res.beta).all()
    np.testing.assert_allclose(res.beta.sum(1), 1.0, rtol=1e-4)


def test_store_grows_with_queries(world):
    train, _, _ = world
    engine = QueryEngine(train, ModelStore(), CFG, kind="vb", seed=0)
    assert len(engine.store) == 0
    engine.execute(Interval(0.0, 100.0), alpha=0.0)
    n1 = len(engine.store)
    assert n1 >= 1
    # second query over a covered range reuses, trains only the gap
    res = engine.execute(Interval(0.0, 150.0), alpha=0.0)
    assert any(m.o == Interval(0.0, 100.0) for m in res.plan.plan) or \
        res.n_trained_tokens > 0


def test_batch_execution_consistent(world):
    train, test, _ = world
    engine = QueryEngine(train, ModelStore(), CFG, kind="vb", seed=0)
    engine.train_range(0.0, 120.0)
    queries = [Interval(0.0, 200.0), Interval(100.0, 300.0)]
    results, opt = engine.execute_batch(queries)
    assert len(results) == 2
    assert opt.benefit >= 0.0
    x_test = doc_term_matrix(test)
    for r in results:
        assert np.isfinite(r.beta).all()
        lpp = log_predictive_probability(r.beta, x_test)
        assert lpp > -np.log(CFG.vocab_size) * 1.5   # sanity: beats uniform-ish


def test_lda_recovers_topics_better_than_random(world):
    """vb_fit on synthetic LDA data beats a random topic matrix on lpp."""
    train, test, beta_true = world
    x = doc_term_matrix(train)
    lam = np.asarray(vb_fit(x, jax.random.PRNGKey(0), CFG))
    beta_hat = lam / lam.sum(1, keepdims=True)
    x_test = doc_term_matrix(test)
    lpp_fit = log_predictive_probability(beta_hat, x_test)
    rng = np.random.default_rng(0)
    beta_rand = rng.dirichlet(np.full(CFG.vocab_size, 0.5), CFG.n_topics)
    lpp_rand = log_predictive_probability(beta_rand, x_test)
    assert lpp_fit > lpp_rand + 0.3, (lpp_fit, lpp_rand)
