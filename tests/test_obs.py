"""Observability layer: the tracing core (span nesting, ambient
context, Chrome export), the metrics registry (labels, exposition,
snapshot), and their integration through MLegoSession / MLegoService —
trace ids surviving coalescing and α-splits, retry instants on the
span tree, Prometheus exposition agreeing with the same-run
ServiceReport, the breaker fed from *direct* session use, per-query
train_device_ms attribution, and HLO-derived span attributes under
``profile=True``."""
import json

import numpy as np
import pytest

from repro.testing.faults import FaultRule, injected

from repro.api import (
    Interval,
    MetricsRegistry,
    MLegoSession,
    QuerySpec,
    RetryPolicy,
    Tracer,
    TransientExecutionError,
)
from repro.configs.lda_default import LDAConfig
from repro.data.corpus import make_corpus, train_test_split
from repro.obs import trace as obs
from repro.serve import MLegoService, SLOPolicy

CFG = LDAConfig(n_topics=6, vocab_size=150, alpha=0.5, eta=0.05,
                max_iters=8, e_step_iters=5, gibbs_sweeps=6)


@pytest.fixture(scope="module")
def train():
    corpus, _ = make_corpus(300, CFG.vocab_size, CFG.n_topics,
                            mean_doc_len=30, seed=3)
    train, _ = train_test_split(corpus, test_frac=0.1, seed=1)
    return train


def _hi(train):
    return float(train.attr[-1]) + 1.0


# ---------------------------------------------------------------------------
# tracing core
# ---------------------------------------------------------------------------

def test_tracer_span_nesting_and_ambient_context():
    tr = Tracer()
    with tr.span("root", "test") as root:
        with obs.span("child", "test", foo=1):
            obs.set_attrs(bar=2)
    spans = tr.spans()
    assert [s.name for s in spans] == ["root", "child"]
    child = spans[1]
    assert child.trace_id == root.trace_id
    assert child.parent_id == root.span_id
    assert child.attrs["foo"] == 1 and child.attrs["bar"] == 2
    assert root.t0 <= child.t0 and child.t1 <= root.t1


def test_ambient_helpers_are_noops_without_enclosing_span():
    # must neither raise nor leak state when no Tracer.span is active
    with obs.span("orphan", "test", x=1):
        obs.set_attrs(y=2)
    obs.instant("orphan.event", z=3)
    assert obs.current_tracer() is None
    assert obs.current_span() is None


def test_tracer_record_external_interval():
    tr = Tracer()
    tid = tr.new_trace_id()
    sid = tr.new_span_id()
    tr.record("queue.wait", "serve", 1.0, 1.5, trace_id=tid,
              span_id=sid, attrs={"tenant": "ana"})
    (s,) = tr.spans(trace_id=tid)
    assert s.name == "queue.wait" and s.span_id == sid
    assert s.t1 - s.t0 == pytest.approx(0.5)


def test_chrome_export_loads_and_carries_ids(tmp_path):
    tr = Tracer()
    with tr.span("root", "test"):
        with obs.span("child", "test"):
            obs.instant("tick", n=1)
    path = tmp_path / "trace.json"
    tr.export_chrome(str(path))
    doc = json.loads(path.read_text())
    events = doc["traceEvents"]
    assert {e["name"] for e in events} >= {"root", "child", "tick"}
    for e in events:
        assert e["ph"] in ("X", "i")
        assert e["ts"] >= 0                      # µs, rebased to epoch
        assert "trace_id" in e["args"]
    durs = [e for e in events if e["ph"] == "X"]
    assert all("dur" in e for e in durs)


def test_disabled_tracer_records_nothing():
    tr = Tracer(enabled=False)
    with tr.span("root", "test"):
        obs.instant("tick")
    assert len(tr.spans()) == 0


def test_retry_lands_instant_on_ambient_span():
    pol = RetryPolicy(max_attempts=3, base_delay_s=0.0)
    tr = Tracer()
    calls = []

    def flaky():
        calls.append(1)
        if len(calls) < 2:
            raise TransientExecutionError("boom")
        return 7

    with tr.span("op", "test"):
        assert pol.run(flaky, site="test.site",
                       sleep=lambda s: None) == 7
    (ev,) = tr.spans(name="retry")
    assert ev.attrs["site"] == "test.site"
    assert ev.attrs["error"] == "TransientExecutionError"
    assert ev.t0 == ev.t1                        # zero-duration instant


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

def test_counter_labels_and_exposition_format():
    reg = MetricsRegistry()
    c = reg.counter("mlego_test_total", "help text",
                    labelnames=("backend",))
    c.inc(backend="host")
    c.inc(2, backend="device")
    text = reg.exposition()
    assert "# HELP mlego_test_total help text" in text
    assert "# TYPE mlego_test_total counter" in text
    assert 'mlego_test_total{backend="host"} 1' in text
    assert 'mlego_test_total{backend="device"} 2' in text
    assert c.total() == 3


def test_histogram_exposition_is_cumulative_with_inf():
    reg = MetricsRegistry()
    h = reg.histogram("mlego_lat_seconds", "lat",
                      labelnames=("backend",), window=8)
    h.observe(0.01, backend="host")
    h.observe(0.3, backend="host")
    text = reg.exposition()
    assert "# TYPE mlego_lat_seconds histogram" in text
    assert 'mlego_lat_seconds_bucket{backend="host",le="+Inf"} 2' in text
    assert 'mlego_lat_seconds_count{backend="host"} 2' in text
    assert 'mlego_lat_seconds_sum{backend="host"} 0.31' in text
    # cumulative: every bucket count is >= its predecessor
    counts = [int(line.rsplit(" ", 1)[1]) for line in text.splitlines()
              if line.startswith("mlego_lat_seconds_bucket")]
    assert counts == sorted(counts)


def test_histogram_view_feeds_slo_policy():
    reg = MetricsRegistry()
    h = reg.histogram("lat", "", labelnames=("backend",), window=64)
    view = h.view(backend="host")
    pol = SLOPolicy(p95_slo_s=0.1, min_samples=8)
    assert pol.level(view) == 0                  # cold window
    for _ in range(20):
        h.observe(0.01, backend="host")
    assert len(view) == 20
    assert pol.level(view) == 0                  # well under SLO
    for _ in range(60):
        h.observe(1.0, backend="host")
    assert view.p95 == pytest.approx(1.0)
    assert pol.level(view) == 3                  # 10x the SLO -> severe


def test_registry_snapshot_mirrors_exposition():
    reg = MetricsRegistry()
    c = reg.counter("mlego_things_total", "things")
    c.inc(5)
    snap = reg.snapshot()
    assert snap["mlego_things_total"]["type"] == "counter"
    assert list(snap["mlego_things_total"]["series"].values()) == [5.0]
    assert "mlego_things_total 5" in reg.exposition()


def test_registry_factories_are_idempotent():
    reg = MetricsRegistry()
    a = reg.counter("mlego_x_total", "x")
    b = reg.counter("mlego_x_total", "x")
    assert a is b
    with pytest.raises(ValueError):
        reg.gauge("mlego_x_total", "type clash")


# ---------------------------------------------------------------------------
# session integration
# ---------------------------------------------------------------------------

def test_session_submit_roots_a_trace(train):
    sess = MLegoSession(train, CFG, seed=0)
    hi = _hi(train)
    rep = sess.submit(QuerySpec(sigma=Interval(0.0, hi)))
    assert rep.trace is not None
    spans = sess.tracer.spans(trace_id=rep.trace)
    names = [s.name for s in spans]
    assert "session.submit" in names and "plan" in names
    root = next(s for s in spans if s.name == "session.submit")
    assert root.parent_id is None
    plan = next(s for s in spans if s.name == "plan")
    assert plan.parent_id == root.span_id
    # every query gets its own trace
    rep2 = sess.submit(QuerySpec(sigma=Interval(0.0, hi)))
    assert rep2.trace is not None and rep2.trace != rep.trace


def test_session_alpha_split_shares_the_batch_trace(train):
    sess = MLegoSession(train, CFG, seed=0)
    hi = _hi(train)
    sess.train_range(0.0, hi)
    br = sess.submit_many([QuerySpec(sigma=Interval(0.0, hi), alpha=a)
                           for a in (0.0, 1.0)])
    assert br.trace is not None
    assert all(r.trace == br.trace for r in br.reports)
    roots = sess.tracer.spans(trace_id=br.trace,
                              name="session.submit_many")
    assert len(roots) == 1, "the α-split must not nest a second root"


def test_device_query_emits_kernel_spans_with_device_ms(train):
    sess = MLegoSession(train, CFG, seed=0, backend="device")
    hi = _hi(train)
    sess.train_range(0.0, hi / 2)
    sess.train_range(hi / 2, hi)
    rep = sess.submit(QuerySpec(sigma=Interval(0.0, hi), alpha=1.0))
    spans = sess.tracer.spans(trace_id=rep.trace)
    launches = [s for s in spans if s.name == "kernel.launch"]
    assert launches, "a device merge must land a kernel.launch span"
    assert launches[0].attrs.get("merge_device_ms", 0.0) > 0.0
    root = next(s for s in spans if s.name == "session.submit")
    # the launch sits somewhere under the query root
    by_id = {s.span_id: s for s in spans}
    cur = launches[0]
    while cur.parent_id is not None:
        cur = by_id[cur.parent_id]
    assert cur is root


def test_profile_mode_lands_hlo_features_on_launch_span(train):
    sess = MLegoSession(train, CFG, seed=0, backend="device",
                        profile=True)
    hi = _hi(train)
    sess.train_range(0.0, hi / 2)
    sess.train_range(hi / 2, hi)
    rep = sess.submit(QuerySpec(sigma=Interval(0.0, hi), alpha=1.0))
    launches = sess.tracer.spans(trace_id=rep.trace,
                                 name="kernel.launch")
    feats = [s for s in launches if "hlo_hbm_bytes" in s.attrs]
    assert feats, "profile=True must land HLO features on the span"
    assert feats[0].attrs["hlo_hbm_bytes"] > 0.0


def test_fallback_replay_stays_in_the_query_trace(train):
    """A device-loss fallback replays the plan downstream inside the
    *same* trace: one root, a ``fallback`` instant naming both ends of
    the hop, and the answer's trace id unchanged."""
    sess = MLegoSession(train, CFG, backend="device", seed=0)
    hi = _hi(train)
    sess.train_range(0.0, hi / 2)
    spec = QuerySpec(sigma=Interval(0.0, hi / 2))
    with injected(FaultRule("backend.merge.device", rate=1.0,
                            kind="device_lost", max_failures=1), seed=2):
        rep = sess.submit(spec)
    assert rep.fallback_from == "device" and rep.backend == "host"
    spans = sess.tracer.spans(trace_id=rep.trace)
    roots = [s for s in spans if s.name == "session.submit"]
    assert len(roots) == 1, "the replay must not mint a second root"
    (fb,) = [s for s in spans if s.name == "fallback"]
    assert fb.attrs["from_backend"] == "device"
    assert fb.attrs["to_backend"] == "host"
    sess._backend_for(QuerySpec(sigma=Interval(0.0, hi / 2),
                                backend="device")).unquarantine()


def test_train_device_ms_is_attributed_per_query(train):
    sess = MLegoSession(train, CFG, seed=0, backend="device")
    hi = _hi(train)
    first = sess.submit(QuerySpec(sigma=Interval(0.0, hi / 2)))
    assert first.train_device_ms > 0.0, "gap training ran on device"
    # identical query is fully capital-served: no training happened on
    # its behalf, so no device training time may be billed to it (the
    # retired shared-counter diff charged whatever ran concurrently)
    second = sess.submit(QuerySpec(sigma=Interval(0.0, hi / 2)))
    assert second.train_device_ms == 0.0


def test_host_queries_never_bill_device_training(train):
    sess = MLegoSession(train, CFG, seed=0, backend="host")
    hi = _hi(train)
    rep = sess.submit(QuerySpec(sigma=Interval(0.0, hi / 3)))
    assert rep.train_device_ms == 0.0


# ---------------------------------------------------------------------------
# service integration
# ---------------------------------------------------------------------------

def test_service_trace_ids_survive_coalescing(train):
    hi = _hi(train)
    with MLegoService(train, CFG, window_s=0.5, max_width=8) as svc:
        svc.train_range(0.0, hi)
        futs = [svc.submit(QuerySpec(sigma=Interval(0.0, hi)),
                           tenant=f"t{i}") for i in range(4)]
        reps = [f.result(timeout=60) for f in futs]
        tracer = svc.tracer
        rep = svc.report()
    traces = [r.trace for r in reps]
    assert len(set(traces)) == 4, "each coalesced query keeps its own id"
    assert rep.max_coalesce_width == 4
    for tid in traces:
        spans = tracer.spans(trace_id=tid)
        names = {s.name for s in spans}
        assert {"serve.query", "queue.wait", "serve.execute"} <= names
        root = next(s for s in spans if s.name == "serve.query")
        for s in spans:
            if s.name in ("queue.wait", "serve.execute"):
                assert s.parent_id == root.span_id
    # one group span fused them, cross-linked from each member
    fuses = tracer.spans(name="serve.fuse")
    assert any(s.attrs.get("width") == 4 for s in fuses)
    execs = [s for t in traces for s in tracer.spans(trace_id=t)
             if s.name == "serve.execute"]
    assert all(s.attrs.get("fused") for s in execs)
    group_ids = {s.attrs.get("group_trace") for s in execs}
    assert len(group_ids) == 1 and group_ids != {""}


def test_service_trace_export_has_five_span_kinds(train, tmp_path):
    hi = _hi(train)
    with MLegoService(train, CFG, backend="device",
                      window_s=0.2, max_width=8) as svc:
        futs = [svc.submit(QuerySpec(sigma=Interval(0.0, hi / 2),
                                     alpha=1.0)) for _ in range(3)]
        for f in futs:
            f.result(timeout=120)
        path = tmp_path / "trace.json"
        svc.export_trace(str(path))
    events = json.loads(path.read_text())["traceEvents"]
    names = {e["name"] for e in events}
    assert len(names & {"serve.query", "queue.wait", "serve.fuse",
                        "serve.execute", "session.submit",
                        "session.submit_many", "plan",
                        "kernel.launch", "device.upload"}) >= 5


def test_service_exposition_matches_same_run_report(train):
    hi = _hi(train)
    with MLegoService(train, CFG, window_s=0.2, max_width=8) as svc:
        svc.train_range(0.0, hi)
        futs = [svc.submit(QuerySpec(sigma=Interval(0.0, hi)),
                           tenant="ana") for _ in range(3)]
        futs.append(svc.submit(
            QuerySpec(sigma=Interval(hi + 10.0, hi + 20.0))))
        for f in futs[:-1]:
            f.result(timeout=60)
        with pytest.raises(ValueError):
            futs[-1].result(timeout=60)
        rep = svc.report()
        text = svc.metrics_text()

    def value(metric, **labels):
        want = metric
        if labels:
            want += "{" + ",".join('%s="%s"' % kv
                                   for kv in sorted(labels.items())) + "}"
        for line in text.splitlines():
            if line.startswith(want + " "):
                return float(line.rsplit(" ", 1)[1])
        # declared but never observed: no sample line, reads as zero
        assert "# TYPE %s " % metric in text
        return 0.0

    assert value("mlego_queries_total") == rep.queries == 4
    assert value("mlego_query_errors_total") == rep.errors == 1
    assert value("mlego_groups_total") == rep.groups
    assert value("mlego_plan_cache_hits_total") == rep.plan_cache_hits
    assert value("mlego_plan_cache_misses_total") == rep.plan_cache_misses
    assert value("mlego_active_sessions") == rep.active_sessions
    # the report embeds the registry snapshot — same objects, no drift
    assert rep.metrics is not None
    assert sum(rep.metrics["mlego_queries_total"]["series"].values()) \
        == rep.queries
    # latency is only observed for answered queries, not failures
    lat = rep.metrics["mlego_serve_latency_seconds"]["series"]
    assert sum(s["count"] for s in lat.values()) == rep.queries - rep.errors


def test_service_slo_snapshot_reads_the_latency_histogram(train):
    hi = _hi(train)
    with MLegoService(train, CFG, window_s=0.0) as svc:
        svc.train_range(0.0, hi)
        for _ in range(3):
            svc.submit(QuerySpec(sigma=Interval(0.0, hi))) \
               .result(timeout=60)
        rep = svc.report()
        view = svc._m_latency.view(backend=svc.backend.name)
    slo = rep.slo[svc.backend.name]
    assert slo.samples == 3 == len(view)
    assert slo.p95_s == pytest.approx(view.p95)
    assert slo.p50_s > 0.0


def test_direct_session_use_feeds_the_breaker(train):
    """Satellite: a tenant holding ``svc.session(...)`` and calling it
    directly used to bypass breaker accounting entirely — the outcome
    hook now fires inside the session itself."""
    hi = _hi(train)
    with MLegoService(train, CFG, window_s=0.0) as svc:
        sess = svc.session("direct")
        sess.train_range(0.0, hi)
        sess.submit(QuerySpec(sigma=Interval(0.0, hi)))
        cb = svc._breaker_for(svc._instance_for(svc.backend.name))
        snap = cb.snapshot()
    assert snap.window >= 1, \
        "direct session success must land in the breaker window"
    assert snap.error_rate == 0.0


def test_service_queries_feed_breaker_exactly_once(train):
    """The worker path must not double-count now that the session hook
    is the single feed: N answered queries -> N breaker outcomes."""
    hi = _hi(train)
    with MLegoService(train, CFG, window_s=0.0) as svc:
        svc.train_range(0.0, hi)
        for _ in range(3):
            svc.submit(QuerySpec(sigma=Interval(0.0, hi))) \
               .result(timeout=60)
        cb = svc._breaker_for(svc._instance_for(svc.backend.name))
        snap = cb.snapshot()
    # train_range is also a session call but goes through submit only
    # for queries; exactly the 3 query outcomes may be in the window
    assert snap.window == 3
