"""Execution-backend contract: Device (Pallas interpret) vs Host
(NumPy) parity on the query hot path, device-cache LRU/invalidation
semantics, batched submit_many launches, and backend selection flow
through QuerySpec/MLegoSession."""
import numpy as np
import pytest

from repro.api import (
    DeviceBackend,
    HostBackend,
    Interval,
    MLegoSession,
    QuerySpec,
    make_backend,
    register_trainer,
)
from repro.api.trainers import get_trainer
from repro.configs.lda_default import LDAConfig
from repro.core.lda import MaterializedModel
from repro.core.store import ModelStore
from repro.data.corpus import make_corpus, train_test_split

CFG = LDAConfig(n_topics=6, vocab_size=150, alpha=0.5, eta=0.05,
                max_iters=6, e_step_iters=5, gibbs_sweeps=6)
RNG = np.random.default_rng(7)


@pytest.fixture(scope="module")
def train():
    corpus, _ = make_corpus(300, CFG.vocab_size, CFG.n_topics,
                            mean_doc_len=30, seed=3)
    train, _ = train_test_split(corpus, test_frac=0.1, seed=1)
    return train


def _seed_store(kind, edges):
    """Store with synthetic mergeable Θ tiling ``edges`` (no training)."""
    store = ModelStore()
    key = "delta_nkv" if kind == "gs" else "lam"
    for lo, hi in zip(edges, edges[1:]):
        theta = {key: RNG.gamma(1.0, 1.0, (CFG.n_topics, CFG.vocab_size))
                 .astype(np.float32)}
        store.add(Interval(lo, hi), 50, 500, kind, theta)
    return store


def _sessions(train, kind, edges=(0.0, 100.0, 200.0, 300.0)):
    store = _seed_store(kind, list(edges))
    host = MLegoSession(train, CFG, store=store, kind=kind, backend="host")
    dev = MLegoSession(train, CFG, store=store, kind=kind, backend="device")
    return host, dev


# ---------------------------------------------------------------------------
# device/host parity (acceptance: identical beta within 1e-5, vb and gs)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["vb", "gs"])
def test_device_matches_host_submit(train, kind):
    host, dev = _sessions(train, kind)
    spec = QuerySpec(sigma=Interval(0.0, 300.0), alpha=1.0)
    rh = host.submit(spec)
    rd = dev.submit(spec)
    assert rh.model_ids == rd.model_ids, "same plan must be merged"
    np.testing.assert_allclose(rh.beta, rd.beta, rtol=1e-5, atol=1e-5)
    assert rh.backend == "host" and rd.backend == "device"
    assert rh.merge_device_ms == 0.0 and rd.merge_device_ms > 0.0


@pytest.mark.parametrize("kind", ["vb", "gs"])
def test_device_matches_host_submit_many(train, kind):
    """submit_many's single padded launch must equal per-query host
    merges — ragged part counts exercise the zero-weight padding."""
    host, dev = _sessions(train, kind)
    specs = [QuerySpec(sigma=Interval(0.0, 300.0), alpha=0.0),
             QuerySpec(sigma=Interval(100.0, 300.0), alpha=0.0),
             QuerySpec(sigma=Interval(0.0, 200.0), alpha=0.0)]
    bh = host.submit_many(specs)
    bd = dev.submit_many(specs)
    assert len(bh) == len(bd) == 3
    for rh, rd in zip(bh, bd):
        np.testing.assert_allclose(rh.beta, rd.beta, rtol=1e-5, atol=1e-5)
    assert bd.backend == "device"
    assert bd.merge_device_ms > 0.0
    assert bd.cache_hits + bd.cache_misses > 0


def test_device_union_predicate_matches_host(train):
    host, dev = _sessions(train, "vb")
    spec = QuerySpec(sigma=[Interval(0.0, 100.0), Interval(200.0, 300.0)],
                     alpha=1.0)
    np.testing.assert_allclose(host.submit(spec).beta, dev.submit(spec).beta,
                               rtol=1e-5, atol=1e-5)


def test_device_trains_gaps_with_kernel_estep(train):
    """Fresh-gap VB training on the device backend goes through the
    fused E-step kernel and still yields a finite, normalized beta."""
    dev = MLegoSession(train, CFG, kind="vb", backend="device")
    rep = dev.submit(QuerySpec(sigma=Interval(0.0, 150.0)))
    assert rep.n_trained_tokens > 0
    assert np.isfinite(rep.beta).all()
    np.testing.assert_allclose(rep.beta.sum(1), 1.0, rtol=1e-4)


# ---------------------------------------------------------------------------
# device cache semantics
# ---------------------------------------------------------------------------

def test_cache_hits_on_repeated_query(train):
    _, dev = _sessions(train, "vb")
    spec = QuerySpec(sigma=Interval(0.0, 300.0), alpha=1.0)
    first = dev.submit(spec)
    assert first.cache_misses == 3 and first.cache_hits == 0
    second = dev.submit(spec)
    assert second.cache_hits == 3 and second.cache_misses == 0
    assert dev.backend.stats.hit_rate == pytest.approx(0.5)


def test_cache_invalidated_on_store_remove(train):
    _, dev = _sessions(train, "vb")
    spec = QuerySpec(sigma=Interval(0.0, 300.0), alpha=1.0)
    rep = dev.submit(spec)
    mid = rep.model_ids[0]
    cache = dev.backend.cache
    assert mid in cache
    dev.store.remove(mid)
    assert mid not in cache, "remove must invalidate the device copy"
    assert dev.backend.stats.cache_invalidations >= 1
    # the surviving entries are untouched
    assert len(cache) == 2


def test_cache_respects_capacity_with_lru_order():
    backend = DeviceBackend(capacity=2)
    models = [
        MaterializedModel(i, Interval(float(i), float(i + 1)), 10, 100, "vb",
                          {"lam": RNG.gamma(1.0, 1.0, (4, 64))
                           .astype(np.float32)})
        for i in range(3)
    ]
    backend.merge(models, "vb", CFG)
    assert len(backend.cache) == 2
    assert backend.stats.cache_evictions == 1
    # ids 1, 2 were touched after 0 -> 0 is the evictee
    assert 0 not in backend.cache
    assert 1 in backend.cache and 2 in backend.cache
    # re-merging the cached pair is all hits
    before = backend.stats
    backend.merge(models[1:], "vb", CFG)
    d = backend.stats.delta(before)
    assert d.cache_hits == 2 and d.cache_misses == 0


def _dummy_model(mid, k=4, v=64):
    return MaterializedModel(mid, Interval(float(mid), float(mid + 1)),
                             10, 100, "vb",
                             {"lam": RNG.gamma(1.0, 1.0, (k, v))
                              .astype(np.float32)})


def test_cache_byte_bound_evicts_lru():
    """max_bytes caps resident parameter bytes alongside the count
    bound — the ROADMAP "count-bounded, not byte-bounded" gap."""
    entry_bytes = 4 * 64 * 4                      # (4, 64) f32
    backend = DeviceBackend(capacity=64, max_bytes=2 * entry_bytes)
    models = [_dummy_model(i) for i in range(3)]
    backend.merge(models, "vb", CFG)
    assert len(backend.cache) == 2, "third entry must evict the LRU"
    assert 0 not in backend.cache
    assert backend.cache.resident_bytes == 2 * entry_bytes
    assert backend.stats.cache_evictions == 1
    assert backend.stats.cache_resident_bytes == 2 * entry_bytes


def test_cache_byte_bound_oversized_model_passes_through():
    backend = DeviceBackend(capacity=64, max_bytes=100)   # < one entry
    backend.merge([_dummy_model(0)], "vb", CFG)
    assert len(backend.cache) == 0, "an over-budget model must not pin HBM"
    assert backend.cache.resident_bytes == 0


def test_oversized_model_does_not_evict_residents():
    """A model bigger than the whole byte budget must pass through
    without wiping the resident working set on its way out (matters
    once heterogeneous (K, V) shards land)."""
    entry_bytes = 4 * 64 * 4
    backend = DeviceBackend(capacity=64, max_bytes=3 * entry_bytes)
    small = [_dummy_model(i) for i in range(2)]
    backend.merge(small, "vb", CFG)
    assert len(backend.cache) == 2
    big = MaterializedModel(9, Interval(9.0, 10.0), 10, 100, "vb",
                            {"lam": RNG.gamma(1.0, 1.0, (16, 256))
                             .astype(np.float32)})    # 4x the budget
    backend.cache.get(big, "lam")                     # miss + pass through
    assert 9 not in backend.cache
    assert 0 in backend.cache and 1 in backend.cache, \
        "residents must survive an oversized pass-through"
    # warm-insert path shares the guard
    assert backend.cache.put(big, "lam") is False
    assert len(backend.cache) == 2
    assert backend.cache.resident_bytes == 2 * entry_bytes


def test_cache_bytes_track_invalidation_and_clear():
    entry_bytes = 4 * 64 * 4
    backend = DeviceBackend(capacity=8)
    store = ModelStore()
    backend.bind_store(store)
    ms = [store.add(Interval(float(i), float(i + 1)), 10, 100, "vb",
                    {"lam": RNG.gamma(1.0, 1.0, (4, 64))
                     .astype(np.float32)}) for i in range(3)]
    backend.merge(ms, "vb", CFG)
    assert backend.cache.resident_bytes == 3 * entry_bytes
    store.remove(ms[1].model_id)
    assert backend.cache.resident_bytes == 2 * entry_bytes
    backend.cache.clear()
    assert backend.cache.resident_bytes == 0


def test_cache_rejects_bad_bounds():
    with pytest.raises(ValueError, match="max_bytes"):
        DeviceBackend(max_bytes=0)


def test_reports_expose_cache_resident_bytes(train):
    _, dev = _sessions(train, "vb")
    rep = dev.submit(QuerySpec(sigma=Interval(0.0, 300.0), alpha=1.0))
    assert rep.cache_resident_bytes == dev.backend.cache.resident_bytes
    assert rep.cache_resident_bytes > 0


# ---------------------------------------------------------------------------
# ragged segmented batch launches (§V.C)
# ---------------------------------------------------------------------------

def test_ragged_batch_single_launch_zero_pad(train):
    """A ragged batch runs as ONE segmented launch with zero pad rows —
    even on the adversarial one-wide-outlier shape that the retired
    bucketed scheme handled worst — while keeping exact parity with
    per-query host merges."""
    host, dev = _sessions(train, "vb",
                          edges=(0.0, 75.0, 150.0, 225.0, 300.0))
    specs = [QuerySpec(sigma=Interval(0.0, 300.0), alpha=0.0),   # 4 parts
             QuerySpec(sigma=Interval(0.0, 75.0), alpha=0.0),    # 1 part
             QuerySpec(sigma=Interval(75.0, 150.0), alpha=0.0),  # 1 part
             QuerySpec(sigma=Interval(150.0, 225.0), alpha=0.0)]  # 1 part
    bh = host.submit_many(specs)
    bd = dev.submit_many(specs)
    for rh, rd in zip(bh, bd):
        np.testing.assert_allclose(rh.beta, rd.beta, rtol=1e-5, atol=1e-5)
    assert bd.pad_rows == 0
    assert dev.backend.stats.pad_rows == 0
    # one segmented launch for the whole batch, not one per bucket
    assert dev.backend.stats.device_launches == 1


def test_uniform_batch_zero_pad(train):
    _, dev = _sessions(train, "vb")
    specs = [QuerySpec(sigma=Interval(0.0, 200.0), alpha=0.0),
             QuerySpec(sigma=Interval(100.0, 300.0), alpha=0.0)]
    bd = dev.submit_many(specs)
    assert bd.pad_rows == 0
    assert np.isfinite(bd.reports[0].beta).all()


def test_volatile_models_bypass_cache():
    backend = DeviceBackend(capacity=8)
    vol = MaterializedModel(-1, Interval(0.0, 1.0), 10, 100, "vb",
                            {"lam": RNG.gamma(1.0, 1.0, (4, 64))
                             .astype(np.float32)})
    backend.merge([vol], "vb", CFG)
    assert len(backend.cache) == 0, "id -1 can never be invalidated"
    assert backend.stats.cache_misses == 1


def test_rebinding_store_clears_cache(train):
    _, dev = _sessions(train, "vb")
    dev.submit(QuerySpec(sigma=Interval(0.0, 300.0), alpha=1.0))
    assert len(dev.backend.cache) == 3
    dev.backend.bind_store(ModelStore())
    assert len(dev.backend.cache) == 0


# ---------------------------------------------------------------------------
# backend selection / fallbacks
# ---------------------------------------------------------------------------

def test_spec_rejects_unknown_backend():
    with pytest.raises(ValueError, match="unknown execution backend"):
        QuerySpec(sigma=Interval(0.0, 10.0), backend="gpu-magic")
    with pytest.raises(ValueError, match="unknown execution backend"):
        make_backend("bogus")


def test_spec_backend_overrides_session_default(train):
    host, _ = _sessions(train, "vb")
    rep = host.submit(QuerySpec(sigma=Interval(0.0, 300.0), alpha=1.0,
                                backend="device"))
    assert rep.backend == "device"
    assert rep.cache_misses > 0
    # the per-session device backend instance is reused across queries
    rep2 = host.submit(QuerySpec(sigma=Interval(0.0, 300.0), alpha=1.0,
                                 backend="device"))
    assert rep2.cache_hits > 0


def test_batch_rejects_mixed_backends(train):
    host, _ = _sessions(train, "vb")
    with pytest.raises(ValueError, match="one execution backend"):
        host.submit_many([
            QuerySpec(sigma=Interval(0.0, 100.0), backend="host"),
            QuerySpec(sigma=Interval(0.0, 100.0), backend="device")])


def test_custom_merge_callable_falls_back_to_host(train):
    """A kind with a custom merge *callable* has no device form; the
    backend must route it through the host merge (counted once per
    merge, in both submit and submit_many)."""
    from repro.core.merge import merge_vb
    from repro.core.lda import topics_from_vb

    def my_merge(models, cfg):
        return topics_from_vb(merge_vb(models, cfg))

    register_trainer("custom_vb", get_trainer("vb"), merge=my_merge)
    try:
        store = _seed_store("custom_vb", [0.0, 150.0, 300.0])
        dev = MLegoSession(train, CFG, store=store, kind="custom_vb",
                           backend="device")
        rep = dev.submit(QuerySpec(sigma=Interval(0.0, 300.0), alpha=1.0))
        assert np.isfinite(rep.beta).all()
        assert dev.backend.stats.host_fallbacks == 1
        assert rep.merge_device_ms == 0.0
        bd = dev.submit_many([QuerySpec(sigma=Interval(0.0, 150.0)),
                              QuerySpec(sigma=Interval(150.0, 300.0))])
        assert len(bd) == 2
        assert dev.backend.stats.host_fallbacks == 3, \
            "exactly one fallback per merge, not double-counted"
    finally:
        from repro.api import trainers as tr
        tr._TRAINERS.pop("custom_vb", None)
        tr._MERGES.pop("custom_vb", None)


def test_custom_kind_on_builtin_family_gets_device_merge(train):
    """merge="vb" means Alg. 1 over theta["lam"] — the device form
    applies to the registered family, not the kind name."""
    register_trainer("my_vb", get_trainer("vb"), merge="vb")
    try:
        store = _seed_store("my_vb", [0.0, 150.0, 300.0])
        host = MLegoSession(train, CFG, store=store, kind="my_vb",
                            backend="host")
        dev = MLegoSession(train, CFG, store=store, kind="my_vb",
                           backend="device")
        spec = QuerySpec(sigma=Interval(0.0, 300.0), alpha=1.0)
        rh, rd = host.submit(spec), dev.submit(spec)
        np.testing.assert_allclose(rh.beta, rd.beta, rtol=1e-5, atol=1e-5)
        assert dev.backend.stats.host_fallbacks == 0
        assert rd.merge_device_ms > 0.0
    finally:
        from repro.api import trainers as tr
        tr._TRAINERS.pop("my_vb", None)
        tr._MERGES.pop("my_vb", None)


def test_device_backend_cannot_be_shared_across_stores(train):
    """Two stores both allocate model id 0 — a shared device cache
    would silently serve one session's parameters to the other."""
    backend = DeviceBackend()
    MLegoSession(train, CFG, store=_seed_store("vb", [0.0, 300.0]),
                 kind="vb", backend=backend)
    with pytest.raises(ValueError, match="one backend per session"):
        MLegoSession(train, CFG, store=_seed_store("vb", [0.0, 300.0]),
                     kind="vb", backend=backend)


def test_store_swap_rebinds_backend_cache(train):
    _, dev = _sessions(train, "vb")
    dev.submit(QuerySpec(sigma=Interval(0.0, 300.0), alpha=1.0))
    assert len(dev.backend.cache) == 3
    dev.store = _seed_store("vb", [0.0, 300.0])
    assert len(dev.backend.cache) == 0, "swap must clear the device cache"
    rep = dev.submit(QuerySpec(sigma=Interval(0.0, 300.0), alpha=1.0))
    assert rep.cache_misses == 1      # the new store's single model
    # invalidation now tracks the new store
    dev.store.remove(rep.model_ids[0])
    assert rep.model_ids[0] not in dev.backend.cache


def test_host_backend_is_default_and_untouched(train):
    host, _ = _sessions(train, "vb")
    rep = host.submit(QuerySpec(sigma=Interval(0.0, 300.0), alpha=1.0))
    assert rep.backend == "host"
    assert rep.merge_device_ms == 0.0
    assert rep.cache_hits == rep.cache_misses == 0
    assert isinstance(host.backend, HostBackend)


# ---------------------------------------------------------------------------
# store change notifications (the invalidation transport)
# ---------------------------------------------------------------------------

def test_store_notifies_listeners():
    store = ModelStore()
    events = []
    store.subscribe(lambda ev, mid: events.append((ev, mid)))
    m = store.add(Interval(0.0, 1.0), 1, 10, "vb",
                  {"lam": np.ones((2, 4), np.float32)})
    store.remove(m.model_id)
    store.remove(m.model_id)        # absent: no duplicate event
    assert events == [("add", m.model_id), ("remove", m.model_id)]
    store.unsubscribe(store._listeners[0])
    store.add(Interval(1.0, 2.0), 1, 10, "vb",
              {"lam": np.ones((2, 4), np.float32)})
    assert len(events) == 2


# ---------------------------------------------------------------------------
# interpret-mode plumbing (the MLEGO_KERNEL_INTERPRET CI switch)
# ---------------------------------------------------------------------------

def test_kernel_interpret_env_forces_interpret(monkeypatch):
    from repro.kernels import common
    monkeypatch.setenv(common.INTERPRET_ENV, "1")
    assert common.default_interpret(None) is True
    assert common.default_interpret(False) is False   # explicit wins
    monkeypatch.setenv(common.INTERPRET_ENV, "0")
    assert common.default_interpret(None) == (not common.on_tpu())
