"""Per-architecture smoke tests: every assigned arch instantiates a
REDUCED same-family config, runs one train step and one prefill+decode
step on CPU, asserting shapes + finiteness.  Also checks the
prefill->decode handoff agrees with the full forward pass (exact for
every layer kind, including the recurrent state re-derivations)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, ALL_SHAPES
from repro.data.lm import make_batch
from repro.distributed.sharding import single_device_env, set_env
from repro.models.model import build_model
from repro.train.optim import OptimizerConfig
from repro.train.trainer import make_train_step

ALL_ARCHS = sorted(ARCHS)


@pytest.fixture(scope="module")
def env():
    return single_device_env()


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_train_step_smoke(arch, env):
    cfg = ARCHS[arch].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    from repro.train.optim import build_optimizer
    opt_cfg = OptimizerConfig(lr=1e-3, warmup_steps=2)
    opt_state = build_optimizer(opt_cfg)[0](params)
    step_fn = jax.jit(make_train_step(model, opt_cfg, env, remat=False))
    batch = make_batch(cfg, 2, 32, seed=0, cursor=0)
    p2, o2, step, metrics = step_fn(params, opt_state,
                                    jnp.zeros((), jnp.int32), batch)
    assert np.isfinite(float(metrics["loss"]))
    assert int(step) == 1
    # params actually moved
    moved = any(
        not np.allclose(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
    assert moved
    # loss decreases over a few steps on a FIXED batch (memorization)
    p, o, s = p2, o2, step
    first = float(metrics["loss"])
    for _ in range(3):
        p, o, s, metrics = step_fn(p, o, s, batch)
    assert float(metrics["loss"]) < first


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_prefill_decode_consistency(arch, env):
    """decode_step(prefill(t[:S])) logits == prefill(t[:S+1]) logits."""
    cfg = ARCHS[arch].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    B, S = 2, 24
    full = make_batch(cfg, B, S + 1, seed=1, cursor=0)
    full.pop("labels")
    prompt = dict(full)
    prompt["tokens"] = full["tokens"][:, :S]
    if "patch_embeds" in prompt:
        p = prompt["patch_embeds"].shape[1]
        assert p <= S
    with set_env(env):
        lg_dec_src, caches = model.prefill(params, prompt, env,
                                           cache_len=S + 4)
        lg_dec, _ = model.decode_step(params, caches,
                                      full["tokens"][:, S:S + 1],
                                      jnp.asarray(S, jnp.int32), env)
        lg_full, _ = model.prefill(params, full, env)
    np.testing.assert_allclose(np.asarray(lg_dec[:, 0]),
                               np.asarray(lg_full[:, 0]),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_decode_loop_finite(arch, env):
    cfg = ARCHS[arch].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(2))
    B, S = 2, 8
    batch = make_batch(cfg, B, S, seed=2, cursor=0)
    batch.pop("labels")
    with set_env(env):
        lg, caches = model.prefill(params, batch, env, cache_len=S + 8)
        tok = jnp.argmax(lg[:, -1], -1)[:, None].astype(jnp.int32)
        for i in range(4):
            lg, caches = model.decode_step(params, caches, tok,
                                           jnp.asarray(S + i, jnp.int32),
                                           env)
            assert bool(jnp.isfinite(lg).all()), (arch, i)
            tok = jnp.argmax(lg[:, -1], -1)[:, None].astype(jnp.int32)


def test_cell_matrix_accounting():
    """40 assigned cells; long_500k runs only for sub-quadratic archs;
    the runnable count matches DESIGN.md §Arch-applicability."""
    from repro.configs import all_cells
    cells = list(all_cells())
    assert len(cells) == 40
    runnable = [c for c in cells if c[2]]
    skipped = [c for c in cells if not c[2]]
    assert len(skipped) == 8          # 8 full-attention archs skip long_500k
    for arch, shape, _ in skipped:
        assert shape.name == "long_500k"
        assert not arch.sub_quadratic


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_param_count_formula_sane(arch):
    """configs/base param accounting within 25% of the real tree."""
    cfg = ARCHS[arch]
    model = build_model(cfg)
    actual = model.param_count()
    formula = cfg.param_count()
    assert 0.6 < formula / actual < 1.67, (arch, formula, actual)
