"""Production hardening of the serving layer: bounded-queue admission
control (shed / displacement / deadlines), cancellation races, the SLO
degradation loop, tenant lifecycle (eviction + RNG-continuous
revival), per-backend worker-pool isolation, and the keyword-only
front door's one-release positional-tenant shim."""
import threading
import time

import numpy as np
import pytest

from repro.api import (
    Interval,
    QuerySpec,
    get_trainer,
    register_trainer,
)
from repro.configs.lda_default import LDAConfig
from repro.data.corpus import make_corpus, train_test_split
from repro.serve import (
    CoalescingQueue,
    DeadlineExceededError,
    LatencyTracker,
    MLegoService,
    PendingQuery,
    ServiceClosedError,
    ShedError,
    SLOPolicy,
    SubmitOptions,
)

CFG = LDAConfig(n_topics=6, vocab_size=150, alpha=0.5, eta=0.05,
                max_iters=8, e_step_iters=5, gibbs_sweeps=6)


@pytest.fixture(scope="module")
def train():
    corpus, _ = make_corpus(300, CFG.vocab_size, CFG.n_topics,
                            mean_doc_len=30, seed=3)
    train, _ = train_test_split(corpus, test_frac=0.1, seed=1)
    return train


def _hi(train):
    return float(train.attr[-1]) + 1.0


def _wait(cond, timeout=10.0, msg="condition"):
    end = time.monotonic() + timeout
    while time.monotonic() < end:
        if cond():
            return
        time.sleep(0.005)
    raise AssertionError(f"timed out waiting for {msg}")


class _gated_trainer:
    """Context manager registering a trainer kind that blocks on a
    gate — lets tests hold a worker mid-execution deterministically."""

    def __init__(self, name="gate_vb"):
        self.name = name
        self.gate = threading.Event()
        self.calls = 0

    def __enter__(self):
        def fn(corpus, cfg, key, _self=self):
            _self.calls += 1
            assert _self.gate.wait(timeout=60), "test gate never opened"
            return get_trainer("vb")(corpus, cfg, key)

        register_trainer(self.name, fn, merge="vb")
        return self

    def __exit__(self, *exc):
        self.gate.set()
        from repro.api import trainers as tr
        tr._TRAINERS.pop(self.name, None)
        tr._MERGES.pop(self.name, None)


def _pending(lo=0.0, hi=10.0, tenant="t", **opts):
    return PendingQuery(spec=QuerySpec(sigma=Interval(lo, hi)),
                        tenant=tenant, options=SubmitOptions(**opts))


# ---------------------------------------------------------------------------
# SubmitOptions / queue-level admission control (no threads)
# ---------------------------------------------------------------------------

def test_submit_options_validation():
    with pytest.raises(ValueError, match="deadline_s"):
        SubmitOptions(deadline_s=0.0)
    with pytest.raises(ValueError, match="deadline_s"):
        SubmitOptions(deadline_s=-1.0)
    with pytest.raises(ValueError, match="max_queue_wait_s"):
        SubmitOptions(max_queue_wait_s=-0.1)
    assert SubmitOptions().priority == 0


def test_queue_rejects_bad_max_queue():
    with pytest.raises(ValueError, match="max_queue"):
        CoalescingQueue(max_queue=0)


def test_bounded_queue_sheds_equal_priority_arrival():
    q = CoalescingQueue(window_s=0.0, max_queue=2)
    q.put(_pending(lo=0.0))
    q.put(_pending(lo=1.0))
    with pytest.raises(ShedError, match="queue full"):
        q.put(_pending(lo=2.0))
    # the queued items were untouched
    assert len(q) == 2
    assert q.shed == 0, "rejection at the submitter is not a displacement"


def test_bounded_queue_displaces_youngest_lower_priority():
    displaced = []
    q = CoalescingQueue(window_s=0.0, max_queue=2,
                        on_shed=displaced.append)
    old = _pending(lo=0.0)
    young = _pending(lo=1.0)
    q.put(old)
    q.put(young)
    urgent = _pending(lo=2.0, priority=5)
    q.put(urgent)                         # full: displaces the youngest
    assert len(q) == 2
    assert q.shed == 1
    assert displaced == [young]
    with pytest.raises(ShedError, match="displaced"):
        young.future.result(timeout=0)
    # priority-first drain: the urgent arrival leads, FIFO below it
    batch = q.drain(timeout=0.05)
    assert [p.seq for p in batch] == [urgent.seq, old.seq]


def test_aged_entry_outranks_fresh_equal_priority_at_drain():
    """Mid-queue aging: past half its max_queue_wait_s an entry drains
    one priority level higher, so work nearing its overwait shed
    climbs ahead of fresh same-priority arrivals."""
    q = CoalescingQueue(window_s=0.0, max_width=8)
    aged = _pending(lo=0.0, max_queue_wait_s=0.1)
    q.put(aged)
    time.sleep(0.06)                      # past the half-wait mark
    fresh = _pending(lo=1.0)
    q.put(fresh)
    capped = _pending(lo=2.0, max_queue_wait_s=10.0)  # far from aging
    q.put(capped)
    batch = q.drain(timeout=0.05)
    # aged leads despite equal nominal priority; the others stay FIFO
    assert [p.seq for p in batch] == [aged.seq, fresh.seq, capped.seq]


def test_aged_entry_is_not_displaced_by_equal_priority_arrival():
    """Displacement sees effective priority too: a query that aged to
    priority+1 is no longer a victim for a priority-1 arrival, while
    an unaged priority-0 neighbor still is."""
    displaced = []
    q = CoalescingQueue(window_s=0.0, max_queue=2,
                        on_shed=displaced.append)
    aging = _pending(lo=0.0, max_queue_wait_s=0.1)
    q.put(aging)
    time.sleep(0.06)                      # aging now drains at prio 1
    unaged = _pending(lo=1.0)             # no wait cap: never ages
    q.put(unaged)
    urgent = _pending(lo=2.0, priority=1)
    q.put(urgent)                         # full queue: must displace
    assert displaced == [unaged], \
        "the aged entry must be spared; the unaged one is the victim"
    assert len(q) == 2
    # and with only aged entries at effective prio 1, an equal arrival
    # is rejected at the door instead of displacing them
    with pytest.raises(ShedError, match="queue full"):
        q.put(_pending(lo=3.0, priority=1))


def test_entries_without_wait_cap_never_age():
    q = CoalescingQueue(window_s=0.0, max_width=8)
    old = _pending(lo=0.0)                # no max_queue_wait_s
    q.put(old)
    time.sleep(0.05)
    fresh = _pending(lo=1.0)
    q.put(fresh)
    batch = q.drain(timeout=0.05)
    assert [p.seq for p in batch] == [old.seq, fresh.seq], \
        "FIFO within a priority, no phantom aging bump"


def test_queue_drains_priority_first_fifo_within():
    q = CoalescingQueue(window_s=0.0, max_width=8)
    a = _pending(lo=0.0, priority=0)
    b = _pending(lo=1.0, priority=2)
    c = _pending(lo=2.0, priority=2)
    d = _pending(lo=3.0, priority=1)
    for p in (a, b, c, d):
        q.put(p)
    batch = q.drain(timeout=0.05)
    assert [p.seq for p in batch] == [b.seq, c.seq, d.seq, a.seq]


def test_steal_takes_pending_without_waiting():
    q = CoalescingQueue(window_s=10.0, max_width=8)   # huge window
    q.put(_pending(lo=0.0))
    q.put(_pending(lo=1.0))
    t0 = time.perf_counter()
    batch = q.steal()
    assert len(batch) == 2
    assert time.perf_counter() - t0 < 1.0, "steal must not hold a window"
    assert q.steal() == []


def test_steal_yields_to_active_drain():
    """A thief never races the home collector: while a windowed drain
    is in progress, steal returns [] immediately."""
    q = CoalescingQueue(window_s=0.5, max_width=8)
    started = threading.Event()
    out = {}

    def home():
        started.set()
        out["batch"] = q.drain(timeout=5.0)

    t = threading.Thread(target=home)
    t.start()
    started.wait(timeout=5)
    time.sleep(0.05)                     # home worker is now blocked inside
    q.put(_pending(lo=0.0))              # wakes the collector
    assert q.steal() == [], "mid-drain steal must back off"
    t.join(timeout=5)
    assert len(out["batch"]) == 1, "the home drain keeps the item"


# ---------------------------------------------------------------------------
# service-level backpressure (gated worker ⇒ deterministic backlog)
# ---------------------------------------------------------------------------

def _svc_kwargs(gate_kind):
    return dict(kind=gate_kind, window_s=0.0, max_width=1,
                workers_per_pool=1, poll_s=0.005)


def _volatile(hi, lo=0.0, **kw):
    return QuerySpec(sigma=Interval(lo, hi), materialize="volatile", **kw)


def test_service_sheds_burst_and_displaces_by_priority(train):
    hi = _hi(train)
    with _gated_trainer() as g:
        with MLegoService(train, CFG, max_queue=2,
                          **_svc_kwargs(g.name)) as svc:
            f1 = svc.submit(_volatile(hi), tenant="a")
            _wait(lambda: g.calls >= 1, msg="worker to pick up f1")
            f2 = svc.submit(_volatile(hi), tenant="b")
            f3 = svc.submit(_volatile(hi), tenant="c")
            with pytest.raises(ShedError, match="queue full"):
                svc.submit(_volatile(hi), tenant="d")
            # a higher-priority arrival displaces the youngest pending
            f_hi = svc.submit(_volatile(hi), tenant="vip", priority=3)
            with pytest.raises(ShedError, match="displaced"):
                f3.result(timeout=5)
            g.gate.set()
            for f in (f1, f2, f_hi):
                assert np.isfinite(f.result(timeout=60).beta).all()
            rep = svc.report()
    assert rep.shed == 2                     # one rejected + one displaced
    assert rep.tenant("d").shed == 1
    assert rep.tenant("c").shed == 1
    assert rep.shed_rate == pytest.approx(2 / 5)
    assert rep.submitted == 5


def test_deadline_rejected_in_queue_but_honored_when_served(train):
    hi = _hi(train)
    with _gated_trainer() as g:
        with MLegoService(train, CFG, **_svc_kwargs(g.name)) as svc:
            f1 = svc.submit(_volatile(hi), tenant="a")
            _wait(lambda: g.calls >= 1, msg="worker to pick up f1")
            doomed = svc.submit(_volatile(hi), tenant="b",
                                deadline_s=0.05)
            roomy = svc.submit(_volatile(hi), tenant="c", deadline_s=60.0)
            time.sleep(0.1)              # the short deadline expires queued
            g.gate.set()
            with pytest.raises(DeadlineExceededError):
                doomed.result(timeout=60)
            assert np.isfinite(f1.result(timeout=60).beta).all()
            assert np.isfinite(roomy.result(timeout=60).beta).all(), \
                "a deadline with headroom must not reject"
            rep = svc.report()
    assert rep.deadline_rejected == 1
    assert rep.tenant("b").deadline_rejected == 1
    assert rep.shed_rate == pytest.approx(1 / 3)


def test_max_queue_wait_sheds_stale_query(train):
    hi = _hi(train)
    with _gated_trainer() as g:
        with MLegoService(train, CFG, **_svc_kwargs(g.name)) as svc:
            f1 = svc.submit(_volatile(hi), tenant="a")
            _wait(lambda: g.calls >= 1, msg="worker to pick up f1")
            stale = svc.submit(_volatile(hi), tenant="b",
                               max_queue_wait_s=0.05)
            time.sleep(0.1)
            g.gate.set()
            with pytest.raises(ShedError, match="max_queue_wait_s"):
                stale.result(timeout=60)
            assert np.isfinite(f1.result(timeout=60).beta).all()
            rep = svc.report()
    assert rep.shed == 1


def test_cancellation_races_admission_and_shed(train):
    """A future cancelled while queued is dropped at admission; one
    cancelled *and* displaced stays cancelled — either way the worker
    survives and keeps serving."""
    hi = _hi(train)
    with _gated_trainer() as g:
        with MLegoService(train, CFG, max_queue=2,
                          **_svc_kwargs(g.name)) as svc:
            f1 = svc.submit(_volatile(hi), tenant="a")
            _wait(lambda: g.calls >= 1, msg="worker to pick up f1")
            doomed = svc.submit(_volatile(hi), tenant="b")
            assert doomed.cancel(), "a queued future must be cancellable"
            filler = svc.submit(_volatile(hi), tenant="c")
            # displacement hits the cancelled future's slot tolerantly
            vip = svc.submit(_volatile(hi), tenant="vip", priority=1)
            g.gate.set()
            assert np.isfinite(f1.result(timeout=60).beta).all()
            assert np.isfinite(vip.result(timeout=60).beta).all()
            assert doomed.cancelled()
            # the pool survived the races: it still answers
            again = svc.submit(_volatile(hi), tenant="a")
            assert np.isfinite(again.result(timeout=60).beta).all()


def test_submit_after_close_raises_typed_error(train):
    svc = MLegoService(train, CFG, window_s=0.0)
    svc.close()
    with pytest.raises(ServiceClosedError):
        svc.submit(QuerySpec(sigma=Interval(0.0, 10.0)))


# ---------------------------------------------------------------------------
# keyword-only front door
# ---------------------------------------------------------------------------

def test_positional_tenant_warns_but_works(train):
    hi = _hi(train)
    with MLegoService(train, CFG, window_s=0.0) as svc:
        svc.train_range(0.0, hi)
        with pytest.warns(DeprecationWarning, match="positional tenant"):
            fut = svc.submit(QuerySpec(sigma=Interval(0.0, hi)), "ana")
        assert np.isfinite(fut.result(timeout=60).beta).all()
        with pytest.raises(TypeError, match="keyword"):
            svc.submit(QuerySpec(sigma=Interval(0.0, hi)), "ana", 1.0)
        rep = svc.report()
    assert rep.tenant("ana").queries == 1


def test_options_object_merges_with_explicit_keywords(train):
    hi = _hi(train)
    base = SubmitOptions(priority=2, deadline_s=60.0)
    with MLegoService(train, CFG, window_s=0.0) as svc:
        svc.train_range(0.0, hi)
        fut = svc.submit(QuerySpec(sigma=Interval(0.0, hi)),
                         tenant="ana", options=base,
                         max_queue_wait_s=30.0)
        assert np.isfinite(fut.result(timeout=60).beta).all()


# ---------------------------------------------------------------------------
# SLO degradation loop
# ---------------------------------------------------------------------------

def test_latency_tracker_percentiles():
    tr = LatencyTracker(window=8)
    for v in (1.0, 2.0, 3.0, 4.0):
        tr.observe(v)
    assert tr.p50 == 3.0                  # nearest-rank on [1,2,3,4]
    assert tr.p95 == 4.0
    assert len(tr) == 4
    for v in (10.0,) * 8:
        tr.observe(v)                     # window bounds: old values age out
    assert tr.p50 == 10.0
    assert LatencyTracker().p95 == 0.0


def test_slo_policy_levels_and_guards():
    pol = SLOPolicy(p95_slo_s=1.0, min_samples=2)
    tr = LatencyTracker()
    tr.observe(100.0)
    assert pol.level(tr) == 0, "min_samples guards a trivial window"
    tr.observe(100.0)
    assert pol.level(tr) == 3
    slow = LatencyTracker()
    for v in (1.5, 1.5):
        slow.observe(v)
    assert pol.level(slow) == 1
    assert pol.alpha_factor(0) == 1.0
    assert pol.alpha_factor(1) == 0.5
    assert pol.alpha_factor(3) == 0.0
    with pytest.raises(ValueError, match="p95_slo_s"):
        SLOPolicy(p95_slo_s=0.0)
    with pytest.raises(ValueError, match="ordered"):
        SLOPolicy(p95_slo_s=1.0, degrade_at=3.0, heavy_at=2.0)


def test_slo_degrades_alpha_pauses_speculation_spares_cached_plans(train):
    hi = _hi(train)
    # an impossible SLO: every answered query blows it, so the second
    # query onward runs at the maximum degradation level
    policy = SLOPolicy(p95_slo_s=1e-7, min_samples=1)
    with MLegoService(train, CFG, window_s=0.0, slo=policy) as svc:
        sp = svc.attach_speculator(start=False)
        svc.train_range(0.0, hi)
        spec_a = QuerySpec(sigma=Interval(0.0, hi), alpha=1.0)
        r1 = svc.submit(spec_a, tenant="ana").result(timeout=60)
        assert r1.degraded == 0, "cold window: no degradation"
        assert r1.spec.alpha == 1.0
        # different predicate, nothing cached: α is forced down
        # (volatile: a persisted gap model would invalidate the plan
        # cache and defeat the cached-plan probe below)
        r2 = svc.submit(QuerySpec(sigma=Interval(0.0, hi / 2), alpha=1.0,
                                  materialize="volatile"),
                        tenant="ana").result(timeout=60)
        assert r2.degraded == 3
        assert r2.spec.alpha == 0.0, "level 3 forces the fast plan"
        # the original-α plan for spec_a IS cached: degradation spares it
        r3 = svc.submit(spec_a, tenant="ana").result(timeout=60)
        assert r3.degraded == 3
        assert r3.spec.alpha == 1.0, \
            "a cached original-α plan must be served, not re-planned"
        assert r3.plan_cached
        # side effects: speculation parked, level on the report
        assert sp.paused
        assert sp.scan_once() == 0, "a paused speculator must not train"
        rep = svc.report()
    assert rep.degraded_queries == 2
    assert rep.degraded_frac == pytest.approx(2 / 3)
    assert rep.slo["host"].level == 3
    assert rep.slo["host"].samples == 3
    assert rep.speculation.paused
    assert rep.speculation.pauses >= 1


def test_no_slo_policy_means_no_degradation(train):
    hi = _hi(train)
    with MLegoService(train, CFG, window_s=0.0) as svc:
        svc.train_range(0.0, hi)
        for _ in range(3):
            r = svc.submit(QuerySpec(sigma=Interval(0.0, hi), alpha=1.0)) \
                .result(timeout=60)
            assert r.degraded == 0
        rep = svc.report()
    assert rep.degraded_queries == 0
    assert rep.slo["host"].level == 0
    assert rep.slo["host"].samples == 3


# ---------------------------------------------------------------------------
# tenant lifecycle: idle-TTL eviction, RNG-continuous revival
# ---------------------------------------------------------------------------

def _two_answers(svc, hi, *, evict):
    spec = QuerySpec(sigma=Interval(hi / 2, hi), materialize="volatile")
    r1 = svc.submit(spec, tenant="ana").result(timeout=60)
    if evict:
        before = svc.session("ana")
        assert svc.evict_idle(idle_s=0.0) == 1
        assert "ana" not in svc.tenants()
        assert svc.session("ana") is not before, "revival builds afresh"
    r2 = svc.submit(spec, tenant="ana").result(timeout=60)
    return r1, r2


def test_eviction_preserves_rng_stream_and_stats(train):
    """A revived tenant continues its exact RNG stream: the answer
    sequence matches an identically-seeded service that never evicted."""
    hi = _hi(train)
    with MLegoService(train, CFG, window_s=0.0, seed=7) as interrupted:
        interrupted.train_range(0.0, hi / 2, tenant="ana")
        a1, a2 = _two_answers(interrupted, hi, evict=True)
        rep = interrupted.report()
    with MLegoService(train, CFG, window_s=0.0, seed=7) as smooth:
        smooth.train_range(0.0, hi / 2, tenant="ana")
        b1, b2 = _two_answers(smooth, hi, evict=False)
    np.testing.assert_array_equal(a1.beta, b1.beta)
    np.testing.assert_array_equal(
        a2.beta, b2.beta)                 # the continuity claim
    assert rep.tenant_evictions == 1
    assert rep.tenant("ana").evictions == 1
    assert rep.tenant("ana").queries == 2, "stats survive eviction"


def test_ttl_sweep_runs_from_idle_workers(train):
    hi = _hi(train)
    with MLegoService(train, CFG, window_s=0.0, poll_s=0.005,
                      tenant_ttl_s=0.05) as svc:
        svc.train_range(0.0, hi, tenant="ana")
        _wait(lambda: "ana" not in svc.tenants(), timeout=10.0,
              msg="idle worker to sweep the idle tenant")
        assert svc.report().tenant_evictions >= 1
        # the tenant is still usable — it just revives
        r = svc.submit(QuerySpec(sigma=Interval(0.0, hi)),
                       tenant="ana").result(timeout=60)
        assert np.isfinite(r.beta).all()


def test_busy_tenant_is_not_evicted(train):
    hi = _hi(train)
    with _gated_trainer() as g:
        with MLegoService(train, CFG, **_svc_kwargs(g.name)) as svc:
            fut = svc.submit(_volatile(hi), tenant="ana")
            _wait(lambda: g.calls >= 1, msg="worker to pick up the query")
            assert svc.evict_idle(idle_s=0.0) == 0, \
                "a tenant with in-flight work must be skipped"
            assert "ana" in svc.tenants()
            g.gate.set()
            assert np.isfinite(fut.result(timeout=60).beta).all()


def test_evict_requires_some_ttl(train):
    with MLegoService(train, CFG, window_s=0.0) as svc:
        with pytest.raises(ValueError, match="TTL"):
            svc.evict_idle()
    with pytest.raises(ValueError, match="tenant_ttl_s"):
        MLegoService(train, CFG, tenant_ttl_s=-1.0)
    with pytest.raises(ValueError, match="workers_per_pool"):
        MLegoService(train, CFG, workers_per_pool=0)


# ---------------------------------------------------------------------------
# per-backend worker pools
# ---------------------------------------------------------------------------

def test_pools_isolate_host_from_stalled_device_traffic(train):
    """A stalled device-pool query must not delay host answers (the
    pre-hardening single loop serialized them)."""
    hi = _hi(train)
    with _gated_trainer() as g:
        with MLegoService(train, CFG, window_s=0.0, poll_s=0.005,
                          workers_per_pool=1) as svc:
            svc.train_range(0.0, hi)
            stalled = svc.submit(
                QuerySpec(sigma=Interval(hi / 2, hi), kind=g.name,
                          backend="device", materialize="volatile"),
                tenant="gpu")
            _wait(lambda: g.calls >= 1, msg="device pool to stall")
            host = svc.submit(QuerySpec(sigma=Interval(0.0, hi)),
                              tenant="cpu")
            rep = host.result(timeout=60)   # resolves while device stalls
            assert np.isfinite(rep.beta).all()
            assert not stalled.done(), \
                "the device query must still be gated when host answers"
            g.gate.set()
            assert np.isfinite(stalled.result(timeout=120).beta).all()
            depth = svc.report().queue_depth
    assert set(depth) == {"host", "device"}, "one pool per backend name"


def test_single_loop_baseline_serializes(train):
    """pool_per_backend=False restores the pre-hardening topology: one
    queue, one loop — a stalled query heads-of-line blocks everyone
    (this is the baseline the bench compares pools against)."""
    hi = _hi(train)
    with _gated_trainer() as g:
        with MLegoService(train, CFG, window_s=0.0, poll_s=0.005,
                          workers_per_pool=1,
                          pool_per_backend=False) as svc:
            svc.train_range(0.0, hi)
            stalled = svc.submit(
                QuerySpec(sigma=Interval(hi / 2, hi), kind=g.name,
                          backend="device", materialize="volatile"),
                tenant="gpu")
            _wait(lambda: g.calls >= 1, msg="the single loop to stall")
            host = svc.submit(QuerySpec(sigma=Interval(0.0, hi)),
                              tenant="cpu")
            time.sleep(0.2)
            assert not host.done(), \
                "single-loop topology serializes host behind device"
            g.gate.set()
            assert np.isfinite(host.result(timeout=60).beta).all()
            assert np.isfinite(stalled.result(timeout=120).beta).all()
            assert set(svc.report().queue_depth) == {"*"}


def test_idle_workers_steal_across_pools(train):
    """With >= 2 workers per pool, a host sibling steals pending device
    work while the device home worker is stalled."""
    hi = _hi(train)
    with _gated_trainer() as g:
        with MLegoService(train, CFG, window_s=0.0, poll_s=0.005,
                          workers_per_pool=2, max_width=1) as svc:
            svc.train_range(0.0, hi)
            stalled = svc.submit(
                QuerySpec(sigma=Interval(hi / 2, hi), kind=g.name,
                          backend="device", materialize="volatile"),
                tenant="gpu")
            _wait(lambda: g.calls >= 1, msg="device home worker to stall")
            # pending device work with its home worker stalled: only a
            # thief can answer it while the gate is closed
            quick = svc.submit(QuerySpec(sigma=Interval(0.0, hi),
                                         backend="device"),
                               tenant="gpu2")
            rep = quick.result(timeout=60)
            assert np.isfinite(rep.beta).all()
            assert not stalled.done()
            g.gate.set()
            assert np.isfinite(stalled.result(timeout=120).beta).all()


# ---------------------------------------------------------------------------
# shared cost provider under concurrent pools
# ---------------------------------------------------------------------------

def test_train_backend_pricing_is_thread_local():
    """Concurrent workers price gap training for different backends on
    one shared provider — the routing attribute must not leak between
    threads."""
    from repro.core.cost import CalibratedCostModel

    cost = CalibratedCostModel()
    assert cost.train_backend == "host", "fresh thread defaults to host"
    seen = {}
    ready = threading.Barrier(2)

    def worker(name):
        cost.set_train_backend(name)
        ready.wait(timeout=5)            # both threads have now written
        time.sleep(0.02)
        seen[name] = cost.train_backend

    ts = [threading.Thread(target=worker, args=(n,))
          for n in ("host", "device")]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert seen == {"host": "host", "device": "device"}
    assert cost.train_backend == "host", \
        "other threads' writes must not leak into this one"
