"""Fault-tolerance: the deterministic injection harness, the typed
retry policy, crash-safe store degradation (checksum quarantine), and
the chaos acceptance trace — an open-loop serve run under 10%+
transient injection on the merge/fetch sites must complete with zero
worker deaths and every future resolved to a report or a typed error.

This file (with ``test_breaker.py``) is the CI chaos-smoke leg.
"""
import os
import subprocess
import sys
import threading
import time

import numpy as np
import pytest

from repro.api import (
    CorruptModelError,
    DeviceLostError,
    Interval,
    MLegoSession,
    PermanentExecutionError,
    QuerySpec,
    RetryPolicy,
    TransientExecutionError,
)
from repro.configs.lda_default import LDAConfig
from repro.core.store import ModelStore
from repro.data.corpus import make_corpus
from repro.distributed.elastic import recover_quarantined
from repro.serve import MLegoService
from repro.testing.faults import (
    FaultInjector,
    FaultRule,
    active_injector,
    from_env,
    injected,
    maybe_fail,
)

CFG = LDAConfig(n_topics=4, vocab_size=100, alpha=0.5, eta=0.05,
                max_iters=5, e_step_iters=4, gibbs_sweeps=4)


@pytest.fixture(scope="module")
def corpus():
    c, _ = make_corpus(200, CFG.vocab_size, CFG.n_topics,
                       mean_doc_len=25, seed=11)
    return c


def _hi(corpus):
    return float(corpus.attr[-1]) + 1.0


# ---------------------------------------------------------------------------
# injector
# ---------------------------------------------------------------------------

def test_injector_verdicts_are_deterministic_per_seed_and_site():
    def verdicts(seed):
        inj = FaultInjector([FaultRule("s.a", rate=0.5),
                             FaultRule("s.b", rate=0.5)], seed=seed)
        out = []
        for site in ["s.a", "s.b"] * 20:
            try:
                inj.check(site)
                out.append(0)
            except TransientExecutionError:
                out.append(1)
        return out

    assert verdicts(7) == verdicts(7)
    assert verdicts(7) != verdicts(8)       # seed actually matters
    assert any(verdicts(7))                 # rate=0.5 fires sometimes
    assert not all(verdicts(7))


def test_site_streams_are_independent():
    """Adding calls at one site never shifts another site's verdicts."""
    def b_verdicts(extra_a_calls):
        inj = FaultInjector([FaultRule("s", rate=0.5)], seed=3)
        for _ in range(extra_a_calls):
            try:
                inj.check("s.a")
            except TransientExecutionError:
                pass
        out = []
        for _ in range(20):
            try:
                inj.check("s.b")
                out.append(0)
            except TransientExecutionError:
                out.append(1)
        return out

    assert b_verdicts(0) == b_verdicts(17)


def test_rule_prefix_after_and_max_failures():
    inj = FaultInjector([FaultRule("backend.merge", rate=1.0,
                                   kind="permanent", after=2,
                                   max_failures=2)], seed=0)
    # prefix match: backend.merge.device is covered, store.get is not
    inj.check("store.get")
    inj.check("backend.merge.device")       # after=2 exempts calls 1..2
    inj.check("backend.merge.device")
    for _ in range(2):                      # then exactly max=2 firings
        with pytest.raises(PermanentExecutionError):
            inj.check("backend.merge.device")
    inj.check("backend.merge.device")       # budget exhausted: clean
    assert inj.total_failures == 2
    assert inj.calls["backend.merge.device"] == 5


def test_kinds_raise_the_right_types():
    for kind, exc in [("transient", TransientExecutionError),
                      ("permanent", PermanentExecutionError),
                      ("device_lost", DeviceLostError),
                      ("corrupt", CorruptModelError),
                      ("io", IOError)]:
        inj = FaultInjector([FaultRule("x", rate=1.0, kind=kind)])
        with pytest.raises(exc):
            inj.check("x")


def test_injected_scope_nests_and_restores():
    assert active_injector() is None
    with injected(FaultRule("a", rate=1.0), seed=1) as outer:
        assert active_injector() is outer
        with injected(FaultRule("b", rate=1.0), seed=2) as inner:
            assert active_injector() is inner
        assert active_injector() is outer
    assert active_injector() is None
    maybe_fail("a")                         # no injector: free no-op


def test_from_env_parses_seed_and_rules():
    inj = from_env("seed=7, backend.merge:0.1, "
                   "store.load:1:corrupt:max=1, s:0.5:io:after=3")
    assert inj.seed == 7
    assert [r.site for r in inj.rules] == ["backend.merge", "store.load",
                                           "s"]
    assert inj.rules[1].kind == "corrupt"
    assert inj.rules[1].max_failures == 1
    assert inj.rules[2].after == 3 and inj.rules[2].kind == "io"
    with pytest.raises(ValueError):
        from_env("justasite")
    with pytest.raises(ValueError):
        from_env("x:2.0")                   # rate out of range


def test_env_hook_installs_at_import():
    """MLEGO_FAULTS is parsed once at module import (the CI hook)."""
    env = dict(os.environ,
               MLEGO_FAULTS="seed=3,store.get:1:io:max=1",
               PYTHONPATH="src")
    code = ("from repro.testing.faults import active_injector\n"
            "inj = active_injector()\n"
            "assert inj is not None and inj.seed == 3, inj\n"
            "assert inj.rules[0].site == 'store.get'\n"
            "print('env-hook-ok')\n")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=120)
    assert out.returncode == 0, out.stderr
    assert "env-hook-ok" in out.stdout


# ---------------------------------------------------------------------------
# retry policy
# ---------------------------------------------------------------------------

def test_retry_absorbs_transients_within_budget():
    pol = RetryPolicy(max_attempts=3, base_delay_s=0.0)
    calls = []

    def flaky():
        calls.append(1)
        if len(calls) < 3:
            raise TransientExecutionError("flake")
        return "ok"

    assert pol.run(flaky, site="s", sleep=lambda _: None) == "ok"
    assert len(calls) == 3
    assert pol.snapshot() == {"s": 2}
    assert pol.total_retries == 2


def test_retry_budget_exhaustion_reraises():
    pol = RetryPolicy(max_attempts=2)

    def always():
        raise TransientExecutionError("never clears")

    with pytest.raises(TransientExecutionError):
        pol.run(always, site="s", sleep=lambda _: None)
    assert pol.snapshot() == {"s": 1}       # one retry, then surfaced


def test_retry_never_retries_permanent_or_no_retry_types():
    pol = RetryPolicy(max_attempts=5)
    n = [0]

    def perm():
        n[0] += 1
        raise CorruptModelError("bad blob")

    with pytest.raises(CorruptModelError):
        pol.run(perm, site="s", sleep=lambda _: None)
    assert n[0] == 1

    def lost():
        n[0] += 1
        raise DeviceLostError("gone", backend="device")

    with pytest.raises(DeviceLostError):
        pol.run(lost, site="s", sleep=lambda _: None,
                no_retry=(DeviceLostError,))
    assert n[0] == 2                        # no blind retry of device loss
    assert pol.total_retries == 0


def test_backoff_is_capped_exponential_with_deterministic_jitter():
    pol = RetryPolicy(base_delay_s=0.01, max_delay_s=0.04, jitter=0.5)
    d = [pol.delay_s(i, "site") for i in range(1, 6)]
    assert d == [pol.delay_s(i, "site") for i in range(1, 6)]  # no RNG
    # monotone-ish growth up to the cap; jitter only shrinks
    for i, di in enumerate(d, start=1):
        nominal = min(0.04, 0.01 * 2 ** (i - 1))
        assert 0.5 * nominal <= di <= nominal
    assert pol.delay_s(1, "a") != pol.delay_s(1, "b")  # site-salted


def test_per_site_budgets_longest_prefix_wins():
    pol = RetryPolicy(max_attempts=3,
                      site_attempts={"backend": 5,
                                     "backend.merge": 1})
    assert pol.attempts_for("backend.train_gap.host") == 5
    assert pol.attempts_for("backend.merge.device") == 1
    assert pol.attempts_for("store.get") == 3


# ---------------------------------------------------------------------------
# executor/session retry integration
# ---------------------------------------------------------------------------

def test_session_absorbs_transient_merge_and_fetch_faults(corpus):
    hi = _hi(corpus)
    sess = MLegoSession(corpus, CFG, seed=0,
                        retry=RetryPolicy(base_delay_s=0.0))
    sess.train_range(0.0, hi / 2)
    with injected(FaultRule("backend.merge", rate=1.0, max_failures=1),
                  FaultRule("store.get", rate=1.0, max_failures=1),
                  seed=5) as inj:
        rep = sess.submit(QuerySpec(sigma=Interval(0.0, hi / 2)))
    assert rep.beta.shape == (CFG.n_topics, CFG.vocab_size)
    assert inj.total_failures == 2          # both faults fired ...
    assert sess.retry.total_retries >= 2    # ... and were retried away


def test_session_surfaces_permanent_fault_immediately(corpus):
    hi = _hi(corpus)
    sess = MLegoSession(corpus, CFG, seed=0,
                        retry=RetryPolicy(base_delay_s=0.0))
    sess.train_range(0.0, hi / 2)
    with injected(FaultRule("backend.merge", rate=1.0, kind="permanent"),
                  seed=5):
        with pytest.raises(PermanentExecutionError):
            sess.submit(QuerySpec(sigma=Interval(0.0, hi / 2)))
    assert sess.retry.total_retries == 0


# ---------------------------------------------------------------------------
# crash-safe store: checksums, quarantine, planning around the hole
# ---------------------------------------------------------------------------

def _filled_store():
    store = ModelStore()
    rng = np.random.default_rng(0)
    for lo in (0.0, 10.0, 20.0):
        store.add(Interval(lo, lo + 10.0), 10, 100, "vb",
                  {"lam": rng.random((4, 32)).astype(np.float32)})
    return store


def test_load_verify_detects_checksum_mismatch(tmp_path):
    store = _filled_store()
    store.save(str(tmp_path))
    blob = tmp_path / "model_1.npz"
    raw = bytearray(blob.read_bytes())
    raw[len(raw) // 2] ^= 0xFF              # flip one byte mid-file
    blob.write_bytes(bytes(raw))

    with pytest.raises(CorruptModelError) as ei:
        ModelStore.load(str(tmp_path), verify=True)
    assert ei.value.model_id == 1
    assert "checksum" in str(ei.value)
    # legacy callers catch IOError — the taxonomy keeps that contract
    with pytest.raises(IOError):
        ModelStore.load(str(tmp_path), verify=True)
    # verify=False skips the hash; the flipped byte still loads or
    # fails as a zip error, but must not raise a *checksum* error
    try:
        ModelStore.load(str(tmp_path), verify=False)
    except CorruptModelError as exc:
        assert "checksum" not in str(exc)


def test_load_quarantines_truncated_blob_and_keeps_the_rest(tmp_path):
    store = _filled_store()
    store.save(str(tmp_path))
    blob = tmp_path / "model_1.npz"
    blob.write_bytes(blob.read_bytes()[:20])  # truncated write / crash

    loaded = ModelStore.load(str(tmp_path), on_corrupt="quarantine")
    assert len(loaded) == 2
    assert len(loaded.quarantined) == 1
    q = loaded.quarantined[0]
    assert q.model_id == 1 and q.o == Interval(10.0, 20.0)
    assert q.kind == "vb" and "checksum" in q.reason
    # healthy blobs are intact
    assert {m.model_id for m in loaded.models()} == {0, 2}

    # without checksums the truncation is caught at deserialization
    raw = ModelStore.load(str(tmp_path), verify=False,
                          on_corrupt="quarantine")
    assert len(raw) == 2
    assert "unreadable" in raw.quarantined[0].reason

    with pytest.raises(ValueError):
        ModelStore.load(str(tmp_path), on_corrupt="nonsense")


def test_save_is_atomic_under_injected_crash(tmp_path):
    """A save that dies mid-write never corrupts the previous good
    snapshot: blobs/manifest go through tmp+fsync+rename."""
    store = _filled_store()
    store.save(str(tmp_path))
    good = ModelStore.load(str(tmp_path))
    assert len(good) == 3

    with injected(FaultRule("store.save", rate=1.0, kind="io"), seed=0):
        with pytest.raises(IOError):
            store.save(str(tmp_path))
    again = ModelStore.load(str(tmp_path), verify=True)
    assert len(again) == 3                  # old snapshot still whole


def test_quarantined_store_still_answers_covering_query(corpus, tmp_path):
    """The acceptance property: one blob lost, queries over its range
    still answer — the planner plans around the hole (alternate cover
    or gap training), it does not error."""
    hi = _hi(corpus)
    sess = MLegoSession(corpus, CFG, seed=0)
    sess.train_range(0.0, hi / 2)
    sess.train_range(hi / 2, hi)
    sess.store.save(str(tmp_path))
    # corrupt the second range's blob on disk
    mid = max(m.model_id for m in sess.store.models())
    blob = tmp_path / f"model_{mid}.npz"
    blob.write_bytes(b"not a zip at all")

    loaded = ModelStore.load(str(tmp_path), on_corrupt="quarantine")
    assert len(loaded.quarantined) == 1
    fresh = MLegoSession(corpus, CFG, store=loaded, seed=1)
    rep = fresh.submit(QuerySpec(sigma=Interval(0.0, hi)))
    assert rep.beta.shape == (CFG.n_topics, CFG.vocab_size)
    assert np.all(np.isfinite(rep.beta))
    # the hole was not silently ignored: the missing range was re-covered
    assert rep.n_trained_tokens > 0


def test_runtime_quarantine_and_elastic_recovery():
    store = _filled_store()
    store.quarantine(1, reason="device loss mid-read")
    assert {m.model_id for m in store.models()} == {0, 2}
    assert store.quarantined[0].o == Interval(10.0, 20.0)

    trained = []

    def train_fn(lo, hi):
        trained.append((lo, hi))
        rng = np.random.default_rng(99)
        return store.add(Interval(lo, hi), 10, 100, "vb",
                         {"lam": rng.random((4, 32)).astype(np.float32)})

    fresh = recover_quarantined(store, train_fn)
    assert trained == [(10.0, 20.0)]        # exactly the hole, nothing else
    assert len(fresh) == 1
    assert store.quarantined == []          # ledger drained (clear=True)
    assert len(store) == 3

    # already-covered holes are not retrained (local recovery only)
    store.quarantine(fresh[0].model_id, reason="again")
    store.add(Interval(10.0, 20.0), 10, 100, "vb",
              {"lam": np.zeros((4, 32), np.float32)})
    trained.clear()
    recover_quarantined(store, train_fn)
    assert trained == []


def test_recover_quarantined_can_keep_ledger():
    store = _filled_store()
    store.quarantine(0)
    recover_quarantined(store, lambda lo, hi: None, clear=False)
    assert len(store.quarantined) == 1


# ---------------------------------------------------------------------------
# calibration sidecar corruption
# ---------------------------------------------------------------------------

def test_corrupt_calibration_sidecar_cold_starts_with_warning(
        corpus, tmp_path):
    path = tmp_path / "calibration.json"
    path.write_text("{ this is not json")
    with pytest.warns(RuntimeWarning, match="cold-starting"):
        sess = MLegoSession(corpus, CFG, cost="calibrated",
                            calibration_path=str(path))
    # the session is usable at analytic prices
    hi = _hi(corpus)
    sess.train_range(0.0, hi / 4)
    rep = sess.submit(QuerySpec(sigma=Interval(0.0, hi / 4)))
    assert np.all(np.isfinite(rep.beta))


def test_missing_calibration_sidecar_stays_silent(corpus, tmp_path):
    import warnings as _w
    with _w.catch_warnings():
        _w.simplefilter("error")            # any warning would raise
        MLegoSession(corpus, CFG, cost="calibrated",
                     calibration_path=str(tmp_path / "absent.json"))


# ---------------------------------------------------------------------------
# serve-layer chaos acceptance
# ---------------------------------------------------------------------------

def _alive_workers(svc):
    return sum(t.is_alive() for p in svc._pools_snapshot()
               for t in p.threads)


def test_worker_survives_injected_worker_faults(corpus):
    hi = _hi(corpus)
    svc = MLegoService(corpus, CFG, backend="host", window_s=0.0)
    try:
        svc.train_range(0.0, hi / 2)
        n0 = _alive_workers(svc)
        spec = QuerySpec(sigma=Interval(0.0, hi / 2))
        with injected(FaultRule("serve.worker", rate=1.0, kind="io",
                                max_failures=2), seed=1):
            futs = [svc.submit(spec) for _ in range(4)]
            outcomes = []
            for f in futs:
                try:
                    outcomes.append(f.result(timeout=60))
                except IOError:
                    outcomes.append("failed")
        assert "failed" in outcomes         # the fault did land
        assert _alive_workers(svc) == n0    # ... and killed no thread
        # the pool still answers after the chaos window
        rep = svc.submit(spec).result(timeout=60)
        assert np.all(np.isfinite(rep.beta))
    finally:
        svc.close()


def test_open_loop_chaos_trace_completes(corpus):
    """Acceptance: 10%+ transient injection on the merge and fetch
    sites; an open-loop trace completes with zero worker deaths and
    every future resolved to a report or a typed error."""
    hi = _hi(corpus)
    svc = MLegoService(corpus, CFG, backend="host", window_s=0.002)
    try:
        svc.train_range(0.0, hi / 2)
        svc.train_range(hi / 2, hi)
        n0 = _alive_workers(svc)
        specs = [QuerySpec(sigma=Interval(0.0, hi * (0.3 + 0.1 * (i % 6))))
                 for i in range(24)]
        with injected(FaultRule("backend.merge", rate=0.1),
                      FaultRule("backend.fetch", rate=0.1),
                      FaultRule("store.get", rate=0.1),
                      seed=13) as inj:
            futs = [svc.submit(s, tenant=f"t{i % 3}")
                    for i, s in enumerate(specs)]
            reports, typed_errors = [], []
            for f in futs:
                try:
                    reports.append(f.result(timeout=120))
                except (TransientExecutionError,
                        PermanentExecutionError) as exc:
                    typed_errors.append(exc)
        assert len(reports) + len(typed_errors) == len(specs)
        assert inj.total_failures > 0       # chaos actually happened
        for rep in reports:
            assert np.all(np.isfinite(rep.beta))
        assert _alive_workers(svc) == n0    # zero worker deaths
        r = svc.report()
        # absorbed transients surface on the report's retry ledger
        assert sum(r.retries.values()) >= 1
        assert "host" in r.breaker          # breaker telemetry present
    finally:
        svc.close()
