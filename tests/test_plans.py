"""Interval algebra + plan generation properties (paper §V.B.3)."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dev dep (see ci.yml)
from hypothesis import given, settings, strategies as st

from repro.core.plans import (
    Interval,
    all_plans,
    children,
    plan_key,
    rl_plans,
    subtract,
    union_length,
    usable,
)


def ivs(draw_lo, draw_len):
    return st.builds(lambda lo, ln: Interval(lo, lo + ln), draw_lo, draw_len)


INTERVALS = ivs(st.floats(0, 90), st.floats(0.5, 30))


class FakeModel:
    _next = [0]

    def __init__(self, o):
        self.o = o
        self.model_id = FakeModel._next[0]
        FakeModel._next[0] += 1


@settings(max_examples=50, deadline=None)
@given(st.lists(INTERVALS, min_size=0, max_size=6), INTERVALS)
def test_subtract_partitions_universe(pieces, universe):
    """uncovered ∪ (pieces ∩ universe) tiles the universe exactly."""
    gaps = subtract(universe, pieces)
    covered = union_length(
        [p.intersect(universe) for p in pieces
         if p.intersect(universe) is not None])
    gap_len = sum(g.length for g in gaps)
    assert gap_len + covered == pytest.approx(universe.length, abs=1e-6)
    for g in gaps:
        assert universe.contains(g)
        for p in pieces:
            assert not g.overlaps(p)


@settings(max_examples=50, deadline=None)
@given(st.lists(INTERVALS, min_size=1, max_size=7))
def test_rl_plans_are_maximal_antichains(ranges):
    query = Interval(0.0, 200.0)
    models = [FakeModel(o) for o in ranges]
    roots = rl_plans(models, query)
    cand = usable(models, query)
    keys = set()
    for p in roots:
        k = plan_key(p)
        assert k not in keys, "duplicate RL plan"
        keys.add(k)
        # pairwise disjoint
        for i in range(len(p)):
            for j in range(i + 1, len(p)):
                assert not p[i].o.overlaps(p[j].o)
        # maximal: no candidate extends it
        for m in cand:
            if m in p:
                continue
            assert any(m.o.overlaps(x.o) for x in p), (
                "RL plan is extendable — not maximal")


@settings(max_examples=30, deadline=None)
@given(st.lists(INTERVALS, min_size=1, max_size=6))
def test_theorem1_every_plan_from_rl_plans(ranges):
    """Thm. 1: every candidate plan is a subset of some RL plan."""
    query = Interval(0.0, 200.0)
    models = [FakeModel(o) for o in ranges]
    roots = rl_plans(models, query)
    root_sets = [set(plan_key(p)) for p in roots]
    for p in all_plans(models, query):
        k = set(plan_key(p))
        assert any(k <= r for r in root_sets), (k, root_sets)


from repro.core.plans import intersect_lists


@settings(max_examples=50, deadline=None)
@given(st.lists(INTERVALS, min_size=0, max_size=6), INTERVALS)
def test_subtract_output_disjoint_sorted_idempotent(pieces, universe):
    """Gaps are sorted, pairwise disjoint, and a fixed point: pulling
    the same pieces out of any gap changes nothing."""
    gaps = subtract(universe, pieces)
    for a, b in zip(gaps, gaps[1:]):
        assert a.hi <= b.lo, "gaps must be sorted and disjoint"
    for g in gaps:
        assert subtract(g, pieces) == [g], "subtract must be idempotent"


@settings(max_examples=50, deadline=None)
@given(st.lists(INTERVALS, min_size=0, max_size=6),
       st.lists(INTERVALS, min_size=0, max_size=6))
def test_union_length_duplication_and_subadditivity(a, b):
    """|∪a| ignores duplicates, is monotone in ⊆, and subadditive."""
    ua, ub, uab = union_length(a), union_length(b), union_length(a + b)
    assert union_length(a + a) == pytest.approx(ua, abs=1e-9)
    assert uab >= max(ua, ub) - 1e-9
    assert uab <= ua + ub + 1e-9


@settings(max_examples=50, deadline=None)
@given(st.lists(INTERVALS, min_size=0, max_size=5),
       st.lists(INTERVALS, min_size=0, max_size=5), INTERVALS)
def test_intersect_lists_commutes_and_conserves_length(a, b, universe):
    """x ∩ y commutes; |σ ∩ pieces| + |gaps| tiles σ exactly (the
    length-conservation identity the planner's coverage math rests on)."""
    ab = intersect_lists(a, b)
    ba = intersect_lists(b, a)
    assert sorted(ab) == sorted(ba)
    covered = union_length(intersect_lists([universe], a))
    gap_len = sum(g.length for g in subtract(universe, a))
    assert covered + gap_len == pytest.approx(universe.length, abs=1e-6)


@settings(max_examples=50, deadline=None)
@given(st.lists(INTERVALS, min_size=1, max_size=6), INTERVALS)
def test_intersect_lists_of_disjoint_inputs_stays_disjoint(pieces, universe):
    """Intersecting two disjoint families (here: gap lists, which
    subtract guarantees disjoint) yields a disjoint family, and
    self-intersection of a disjoint family is the identity."""
    gaps_a = subtract(universe, pieces[:3])
    gaps_b = subtract(universe, pieces[3:])
    out = intersect_lists(gaps_a, gaps_b)
    for x, y in zip(out, out[1:]):
        assert x.hi <= y.lo
    assert intersect_lists(gaps_a, gaps_a) == sorted(gaps_a)


def test_children_removes_exactly_one():
    ms = [FakeModel(Interval(i * 10.0, i * 10.0 + 5)) for i in range(4)]
    plan = tuple(ms)
    kids = children(plan)
    assert len(kids) == 4
    for kid in kids:
        assert len(kid) == 3
        assert set(plan_key(kid)) < set(plan_key(plan))


def test_interval_rejects_inverted():
    with pytest.raises(ValueError):
        Interval(5.0, 1.0)
