"""Fault tolerance: atomic checkpointing, bit-identical restart,
corruption detection, keep-N pruning, store persistence."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.core.plans import Interval
from repro.core.store import ModelStore
from repro.data.lm import batch_stream
from repro.distributed.checkpoint import CheckpointManager
from repro.distributed.sharding import single_device_env
from repro.models.model import build_model
from repro.train.optim import OptimizerConfig
from repro.train.trainer import Trainer


def test_roundtrip_pytree(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=2)
    tree = {"a": np.arange(10, dtype=np.float32),
            "b": {"c": np.ones((3, 4), np.int32),
                  "d": [np.zeros(2), np.full((2, 2), 7.0)]}}
    cm.save(tree, meta={"step": 5, "data_cursor": 9}, step=5)
    loaded, meta = cm.restore(5)
    assert meta["step"] == 5 and meta["data_cursor"] == 9
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(loaded)):
        np.testing.assert_array_equal(a, b)


def test_keep_n_pruning(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        cm.save({"x": np.full(3, s)}, step=s)
    steps = [s for s, _ in cm._step_dirs()]
    assert steps == [3, 4]
    assert cm.latest_step() == 4


def test_corruption_detected(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=2)
    cm.save({"x": np.arange(100.0)}, step=1)
    d = os.path.join(str(tmp_path), "step_000000001")
    blob = [f for f in os.listdir(d) if f.endswith(".npy")][0]
    with open(os.path.join(d, blob), "r+b") as f:
        f.seek(-4, 2)
        f.write(b"\xde\xad\xbe\xef")
    with pytest.raises(IOError):
        cm.restore(1)


def test_trainer_restart_bit_identical(tmp_path):
    """Train 6 steps; kill; restore at 4; resume 2 -> identical to the
    uninterrupted run (deterministic data cursor + jit determinism)."""
    cfg = ARCHS["smollm-360m"].reduced()
    env = single_device_env()
    model = build_model(cfg)
    opt = OptimizerConfig(lr=1e-3, warmup_steps=2)

    # uninterrupted reference
    t0 = Trainer(model, opt, env, ckpt_dir=None, remat=False)
    s = t0.init_state()
    s = t0.fit(s, batch_stream(cfg, 2, 16, seed=0), 6, log_every=0)
    ref = jax.tree.leaves(s.params)

    # interrupted: save at 4, new process-equivalent restore, 2 more
    t1 = Trainer(model, opt, env, ckpt_dir=str(tmp_path), save_every=4,
                 remat=False)
    s1 = t1.init_state()
    s1 = t1.fit(s1, batch_stream(cfg, 2, 16, seed=0), 4, log_every=0)
    t2 = Trainer(model, opt, env, ckpt_dir=str(tmp_path), save_every=100,
                 remat=False)
    s2 = t2.restore_or_init()
    assert int(s2.step) == 4
    s2 = t2.fit(s2, batch_stream(cfg, 2, 16, seed=0,
                                 start_cursor=s2.data_cursor),
                2, log_every=0)
    out = jax.tree.leaves(s2.params)
    for a, b in zip(ref, out):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_model_store_roundtrip(tmp_path):
    store = ModelStore()
    rng = np.random.default_rng(0)
    for i in range(3):
        store.add(Interval(i * 10.0, i * 10.0 + 5), 10, 100, "vb",
                  {"lam": rng.gamma(1.0, 1.0, (4, 16)).astype(np.float32)})
    store.save(str(tmp_path / "store"))
    loaded = ModelStore.load(str(tmp_path / "store"))
    assert len(loaded) == 3
    for m in store.models():
        m2 = loaded.get(m.model_id)
        assert m2.o == m.o and m2.n_tokens == m.n_tokens
        np.testing.assert_array_equal(m.lam, m2.lam)
    # store checksum verification
    blob = os.path.join(str(tmp_path / "store"), "model_0.npz")
    with open(blob, "r+b") as f:
        f.seek(-1, 2)
        last = f.read(1)
        f.seek(-1, 2)
        f.write(bytes([last[0] ^ 0xFF]))
    with pytest.raises(IOError):
        ModelStore.load(str(tmp_path / "store"))
