"""Batch query optimization (Alg. 4, Thm. 5/6 problem).

The property tests use ``hypothesis``, an *optional* dev dependency
(see .github/workflows/ci.yml for the pinned version).  On
environments without it this module is skipped instead of erroring the
whole collection.
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.batch_opt import (
    batch_optimize,
    batch_oracle,
    shared_time_and_benefit,
)
from repro.core.cost import CostModel
from repro.core.plans import Interval
from repro.core.search import psoa_search
from tests.conftest import build_store


def _setup(seed, n_models=6):
    from repro.data.corpus import DataIndex, make_corpus
    corpus, _ = make_corpus(250, 64, 4, mean_doc_len=10, seed=13)
    index = DataIndex(corpus)
    store = build_store(index, n_models=n_models, seed=seed,
                        span=(0.0, 250.0), k=4, v=64)
    cost = CostModel(max_iters=8, n_topics=4)
    return index, store, cost


QUERIES = [Interval(5.0, 120.0), Interval(60.0, 200.0), Interval(0.0, 90.0)]


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 1000))
def test_heuristic_no_worse_than_default(seed):
    index, store, cost = _setup(seed)
    h = batch_optimize(store.models(), QUERIES, index, cost)
    default = [psoa_search(store.models(), q, index, cost, 0.0).plan
               for q in QUERIES]
    t_def, _, _ = shared_time_and_benefit(default, QUERIES, index, cost)
    assert h.total_time <= t_def + 1e-12


@settings(max_examples=5, deadline=None)
@given(st.integers(0, 1000))
def test_oracle_no_worse_than_heuristic(seed):
    index, store, cost = _setup(seed, n_models=5)
    h = batch_optimize(store.models(), QUERIES, index, cost)
    o = batch_oracle(store.models(), QUERIES, index, cost)
    assert o.total_time <= h.total_time + 1e-12


def test_benefit_is_naive_minus_shared():
    index, store, cost = _setup(1)
    h = batch_optimize(store.models(), QUERIES, index, cost)
    t, naive, b = shared_time_and_benefit(h.plans, QUERIES, index, cost)
    assert b == pytest.approx(naive - t, rel=1e-9)
    assert b >= 0.0


def test_single_query_batch_degenerates():
    index, store, cost = _setup(2)
    h = batch_optimize(store.models(), [QUERIES[0]], index, cost)
    assert h.benefit == pytest.approx(0.0, abs=1e-12)
