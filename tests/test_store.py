"""ModelStore persistence: atomic save/load and stale-blob pruning."""
import os

import numpy as np
import pytest

from repro.core.plans import Interval
from repro.core.store import ModelStore


def _add(store, lo, hi, k=4, v=32):
    return store.add(Interval(lo, hi), 10, 100, "vb",
                     {"lam": np.random.default_rng(int(lo)).random(
                         (k, v)).astype(np.float32)})


def test_save_load_round_trip(tmp_path):
    store = ModelStore()
    m1 = _add(store, 0.0, 100.0)
    m2 = _add(store, 100.0, 200.0)
    store.save(str(tmp_path))

    loaded = ModelStore.load(str(tmp_path))
    assert len(loaded) == 2
    for m in (m1, m2):
        got = loaded.get(m.model_id)
        assert got.o == m.o and got.kind == m.kind
        np.testing.assert_array_equal(got.theta["lam"], m.theta["lam"])
    # ids keep advancing after reload (no collision with pruned models)
    m3 = _add(loaded, 200.0, 300.0)
    assert m3.model_id > max(m1.model_id, m2.model_id)


def test_save_prunes_stale_blobs(tmp_path):
    """save -> remove -> save -> load: the removed model's blob must be
    pruned from disk, and the reloaded store must match exactly."""
    path = str(tmp_path)
    store = ModelStore()
    keep = _add(store, 0.0, 100.0)
    dead = _add(store, 100.0, 200.0)
    store.save(path)
    assert os.path.exists(os.path.join(path, f"model_{dead.model_id}.npz"))

    store.remove(dead.model_id)
    store.save(path)

    files = sorted(os.listdir(path))
    assert f"model_{dead.model_id}.npz" not in files, \
        "stale blob of a removed model leaked on disk"
    assert files == ["manifest.json", f"model_{keep.model_id}.npz"]

    loaded = ModelStore.load(path)
    assert len(loaded) == 1
    np.testing.assert_array_equal(loaded.get(keep.model_id).theta["lam"],
                                  keep.theta["lam"])


def test_save_prune_ignores_foreign_files(tmp_path):
    """Only our own model_*.npz blobs are pruned — user files survive."""
    path = str(tmp_path)
    store = ModelStore()
    _add(store, 0.0, 100.0)
    foreign = os.path.join(path, "notes.txt")
    os.makedirs(path, exist_ok=True)
    with open(foreign, "w") as f:
        f.write("keep me")
    other_npz = os.path.join(path, "embedding.npz")
    np.savez(other_npz, x=np.zeros(3))
    store.save(path)
    assert os.path.exists(foreign)
    assert os.path.exists(other_npz)


def test_fresh_store_save_keeps_unknown_blobs(tmp_path):
    """A store that never saw a model id must not prune its blob: a
    fresh (or stale) store saving into a shared/snapshot directory is
    not allowed to destroy other snapshots' data."""
    path = str(tmp_path)
    old = ModelStore()
    kept = _add(old, 0.0, 100.0)
    old.save(path)

    ModelStore().save(path)   # fresh store, knows nothing
    assert os.path.exists(os.path.join(path, f"model_{kept.model_id}.npz")), \
        "fresh store pruned a blob it never allocated"


def test_repeated_save_remove_cycles(tmp_path):
    path = str(tmp_path)
    store = ModelStore()
    ids = [_add(store, 100.0 * i, 100.0 * (i + 1)).model_id
           for i in range(4)]
    store.save(path)
    for mid in ids[:3]:
        store.remove(mid)
        store.save(path)
    blobs = [f for f in os.listdir(path) if f.endswith(".npz")]
    assert blobs == [f"model_{ids[3]}.npz"]
    assert len(ModelStore.load(path)) == 1
