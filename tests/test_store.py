"""ModelStore persistence (atomic save/load, stale-blob pruning) and
the subscribe/unsubscribe channel under two sessions sharing one
store (the serving layer's invalidation transport)."""
import os

import numpy as np
import pytest

from repro.core.plans import Interval
from repro.core.store import ModelStore


def _add(store, lo, hi, k=4, v=32):
    return store.add(Interval(lo, hi), 10, 100, "vb",
                     {"lam": np.random.default_rng(int(lo)).random(
                         (k, v)).astype(np.float32)})


def test_save_load_round_trip(tmp_path):
    store = ModelStore()
    m1 = _add(store, 0.0, 100.0)
    m2 = _add(store, 100.0, 200.0)
    store.save(str(tmp_path))

    loaded = ModelStore.load(str(tmp_path))
    assert len(loaded) == 2
    for m in (m1, m2):
        got = loaded.get(m.model_id)
        assert got.o == m.o and got.kind == m.kind
        np.testing.assert_array_equal(got.theta["lam"], m.theta["lam"])
    # ids keep advancing after reload (no collision with pruned models)
    m3 = _add(loaded, 200.0, 300.0)
    assert m3.model_id > max(m1.model_id, m2.model_id)


def test_save_prunes_stale_blobs(tmp_path):
    """save -> remove -> save -> load: the removed model's blob must be
    pruned from disk, and the reloaded store must match exactly."""
    path = str(tmp_path)
    store = ModelStore()
    keep = _add(store, 0.0, 100.0)
    dead = _add(store, 100.0, 200.0)
    store.save(path)
    assert os.path.exists(os.path.join(path, f"model_{dead.model_id}.npz"))

    store.remove(dead.model_id)
    store.save(path)

    files = sorted(os.listdir(path))
    assert f"model_{dead.model_id}.npz" not in files, \
        "stale blob of a removed model leaked on disk"
    assert files == ["manifest.json", f"model_{keep.model_id}.npz"]

    loaded = ModelStore.load(path)
    assert len(loaded) == 1
    np.testing.assert_array_equal(loaded.get(keep.model_id).theta["lam"],
                                  keep.theta["lam"])


def test_save_prune_ignores_foreign_files(tmp_path):
    """Only our own model_*.npz blobs are pruned — user files survive."""
    path = str(tmp_path)
    store = ModelStore()
    _add(store, 0.0, 100.0)
    foreign = os.path.join(path, "notes.txt")
    os.makedirs(path, exist_ok=True)
    with open(foreign, "w") as f:
        f.write("keep me")
    other_npz = os.path.join(path, "embedding.npz")
    np.savez(other_npz, x=np.zeros(3))
    store.save(path)
    assert os.path.exists(foreign)
    assert os.path.exists(other_npz)


def test_fresh_store_save_keeps_unknown_blobs(tmp_path):
    """A store that never saw a model id must not prune its blob: a
    fresh (or stale) store saving into a shared/snapshot directory is
    not allowed to destroy other snapshots' data."""
    path = str(tmp_path)
    old = ModelStore()
    kept = _add(old, 0.0, 100.0)
    old.save(path)

    ModelStore().save(path)   # fresh store, knows nothing
    assert os.path.exists(os.path.join(path, f"model_{kept.model_id}.npz")), \
        "fresh store pruned a blob it never allocated"


def test_repeated_save_remove_cycles(tmp_path):
    path = str(tmp_path)
    store = ModelStore()
    ids = [_add(store, 100.0 * i, 100.0 * (i + 1)).model_id
           for i in range(4)]
    store.save(path)
    for mid in ids[:3]:
        store.remove(mid)
        store.save(path)
    blobs = [f for f in os.listdir(path) if f.endswith(".npz")]
    assert blobs == [f"model_{ids[3]}.npz"]
    assert len(ModelStore.load(path)) == 1


# ---------------------------------------------------------------------------
# subscribe/unsubscribe under two sessions sharing one store
# ---------------------------------------------------------------------------

def test_subscribe_is_idempotent():
    """Two sessions binding one shared cache subscribe its listener
    once — a mutation must reach it exactly once, not once per
    session."""
    store = ModelStore()
    events = []

    def listener(ev, mid):
        events.append((ev, mid))

    store.subscribe(listener)
    store.subscribe(listener)            # second session, same callback
    m = _add(store, 0.0, 100.0)
    assert events == [("add", m.model_id)], \
        "a double-subscribed listener fired more than once"


def test_interleaved_mutation_reaches_both_sessions_caches():
    """Two sessions over one store, each with its own plan cache plus
    one shared device LRU: every mutation — from either session —
    must invalidate all three exactly once."""
    from repro.api import DeviceBackend, MLegoSession, QuerySpec
    from repro.configs.lda_default import LDAConfig
    from repro.data.corpus import make_corpus

    cfg = LDAConfig(n_topics=4, vocab_size=60, max_iters=4,
                    e_step_iters=3, gibbs_sweeps=3)
    corpus, _ = make_corpus(80, cfg.vocab_size, cfg.n_topics,
                            mean_doc_len=10, seed=2)
    hi = float(corpus.attr[-1]) + 1.0
    store = ModelStore()
    backend = DeviceBackend()            # shared LRU
    a = MLegoSession(corpus, cfg, store=store, backend=backend, seed=0)
    b = MLegoSession(corpus, cfg, store=store, backend=backend, seed=1)

    # store.subscribe holds exactly one listener per distinct cache:
    # a's plan cache, b's plan cache, the shared device LRU
    assert len(store._listeners) == 3

    m = a.train_range(0.0, hi)           # mutate from session a
    spec = QuerySpec(sigma=Interval(0.0, hi), alpha=1.0)
    a.submit(spec)
    b.submit(spec)
    assert len(a.plan_cache) == 1 and len(b.plan_cache) == 1
    assert m.model_id in backend.cache

    inv_dev = backend.cache.invalidations
    pa, pb = a.plan_cache.invalidations, b.plan_cache.invalidations
    store.remove(m.model_id)             # interleaved mutation
    assert m.model_id not in backend.cache
    assert backend.cache.invalidations == inv_dev + 1, \
        "shared device LRU must invalidate exactly once"
    assert a.plan_cache.invalidations == pa + 1
    assert b.plan_cache.invalidations == pb + 1
    assert len(a.plan_cache) == len(b.plan_cache) == 0

    # swapping the store under the *shared* backend would rebind the
    # LRU out from under the other session — it must refuse
    with pytest.raises(ValueError, match="adopted execution backend"):
        a.store = ModelStore()
    assert backend.bound_store is store, "shared LRU must stay homed"

    # a session-private cache unsubscribes on swap without detaching
    # the other session's (host sessions: no shared backend involved)
    c = MLegoSession(corpus, cfg, store=store, seed=2)
    d = MLegoSession(corpus, cfg, store=store, seed=3)
    n = len(store._listeners)
    c.store = ModelStore()
    assert len(store._listeners) == n - 1, \
        "only the swapping session's cache may unsubscribe"
    m2 = b.train_range(0.0, hi / 2)      # d (and b) still hear this store
    assert m2 is not None
    assert len(d.plan_cache) == 0

